//! Image consistency checker and repairer — the `qemu-img check
//! [--repair]` analogue. Used by integration tests after every mutating
//! operation sequence, exposed through the CLI (`sqemu check [--repair]`)
//! and run by the coordinator's crash-recovery pass before a node's
//! images serve guest I/O again.
//!
//! Repair relies on the metadata write-ordering rules of DESIGN.md §10
//! (data before mapping, refcount before reference, header flips via
//! checksummed double slot): under those rules the L1/L2 walk is always
//! the ground truth after a crash, so refcounts can be rebuilt from it,
//! dangling mappings cleared, and orphaned tail clusters truncated — the
//! only state a crash can lose is data that was never acknowledged as
//! flushed.

use super::chain::Chain;
use super::entry::{ClusterLoc, L2Entry};
use super::image::Image;
use super::layout::ENTRY_SIZE;
use crate::storage::backend::write_u64;
use crate::util::div_ceil;
use anyhow::Result;
use std::collections::HashMap;

/// Outcome of checking one image.
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    /// Hard inconsistencies (corruption): misaligned/out-of-range offsets,
    /// reachable clusters with zero refcount, bad stamps.
    pub errors: Vec<String>,
    /// Clusters with a refcount but unreachable from any table (space
    /// leaks; tolerated, like `qemu-img check` leaks).
    pub leaked_clusters: u64,
    /// Reachable, correctly refcounted clusters.
    pub ok_clusters: u64,
}

impl CheckReport {
    pub fn is_clean(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Check structural consistency of a single image.
pub fn check_image(img: &Image) -> Result<CheckReport> {
    let geom = *img.geom();
    let cs = geom.cluster_size();
    let file_len = img.file_len();
    let own = img.chain_index();
    let mut report = CheckReport::default();
    // expected refcounts: cluster index -> count
    let mut expected: HashMap<u64, u16> = HashMap::new();
    for c in 0..geom.first_free_cluster() {
        expected.insert(c, 1);
    }

    for l1_idx in 0..geom.l1_entries() {
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            continue;
        }
        if l2_off % cs != 0 {
            report
                .errors
                .push(format!("L1[{l1_idx}] misaligned L2 offset {l2_off:#x}"));
            continue;
        }
        if l2_off >= file_len {
            report
                .errors
                .push(format!("L1[{l1_idx}] L2 offset {l2_off:#x} beyond EOF"));
            continue;
        }
        *expected.entry(l2_off / cs).or_default() += 1;
        let entries = img.read_l2_slice(l2_off, 0, geom.entries_per_l2())?;
        for (l2_idx, &raw) in entries.iter().enumerate() {
            let e = L2Entry(raw);
            if e.is_zero() {
                continue;
            }
            if !e.descriptor_valid() {
                report.errors.push(format!(
                    "L2[{l1_idx}/{l2_idx}] invalid cluster descriptor in {:#x}",
                    e.host_offset()
                ));
                continue;
            }
            let off = e.data_offset();
            // plain data clusters live on cluster boundaries; compressed
            // payloads are sector-aligned by the descriptor encoding and
            // zero clusters have no offset at all
            if e.descriptor() == 0 && off % cs != 0 {
                report.errors.push(format!(
                    "L2[{l1_idx}/{l2_idx}] misaligned data offset {off:#x}"
                ));
                continue;
            }
            match e.bfi() {
                Some(bfi) if e.is_allocated_here() && bfi != own => {
                    report.errors.push(format!(
                        "L2[{l1_idx}/{l2_idx}] local entry stamped {bfi} != own {own}"
                    ));
                }
                Some(bfi) if !e.is_allocated_here() && bfi >= own => {
                    report.errors.push(format!(
                        "L2[{l1_idx}/{l2_idx}] remote stamp {bfi} not below own {own}"
                    ));
                }
                _ => {}
            }
            if e.is_allocated_here() && !e.is_zero_cluster() {
                // compressed payloads must end inside the file; plain
                // clusters must start inside it
                let end = if e.is_compressed() {
                    off + compressed_stored_len(&e, cs)
                } else {
                    off + 1
                };
                if end > file_len {
                    report.errors.push(format!(
                        "L2[{l1_idx}/{l2_idx}] data offset {off:#x} beyond EOF"
                    ));
                    continue;
                }
                // a compressed entry references the shared host cluster
                // containing its payload (several payloads may sum on
                // one cluster); a zero entry references nothing
                *expected.entry(off / cs).or_default() += 1;
            }
        }
    }

    // refcount blocks are themselves refcounted
    let max_cluster = div_ceil(file_len, cs);
    let reftable =
        img.read_l2_slice(geom.reftable_offset(), 0, geom.reftable_clusters() * cs / 8)?;
    for &block_off in reftable.iter().filter(|&&o| o != 0) {
        if block_off % cs != 0 || block_off >= file_len {
            report
                .errors
                .push(format!("refcount block offset {block_off:#x} invalid"));
            continue;
        }
        *expected.entry(block_off / cs).or_default() += 1;
    }

    // compare expected vs stored refcounts
    for cluster in 0..max_cluster {
        let stored = stored_refcount(img, cluster)?;
        let exp = expected.get(&cluster).copied().unwrap_or(0);
        if stored == exp {
            if exp > 0 {
                report.ok_clusters += 1;
            }
        } else if stored > exp {
            // over-refcounted (or allocated but unreachable): a leak
            report.leaked_clusters += 1;
        } else {
            report.errors.push(format!(
                "cluster {cluster}: refcount {stored} < expected {exp}"
            ));
        }
    }
    Ok(report)
}

/// Check a whole chain: every image individually, plus cross-file stamp
/// validity (remote offsets must exist in the referenced file).
pub fn check_chain(chain: &Chain) -> Result<CheckReport> {
    let mut total = CheckReport::default();
    for (pos, img) in chain.images().iter().enumerate() {
        let r = check_image(img)?;
        total.errors.extend(
            r.errors
                .into_iter()
                .map(|e| format!("[{}] {e}", img.name)),
        );
        total.leaked_clusters += r.leaked_clusters;
        total.ok_clusters += r.ok_clusters;
        if img.chain_index() as usize != pos {
            total.errors.push(format!(
                "[{}] chain_index {} but position {pos}",
                img.name,
                img.chain_index()
            ));
        }
        // remote stamps must reference an existing cluster of the target
        if img.has_bfi() {
            let geom = *img.geom();
            for l1_idx in 0..geom.l1_entries() {
                let l2_off = img.l1_entry(l1_idx);
                if l2_off == 0 {
                    continue;
                }
                let entries = img.read_l2_slice(l2_off, 0, geom.entries_per_l2())?;
                for (l2_idx, &raw) in entries.iter().enumerate() {
                    let e = L2Entry(raw);
                    let Some(bfi) = e.bfi() else { continue };
                    if e.is_allocated_here() {
                        continue;
                    }
                    match chain.get(bfi) {
                        None => total.errors.push(format!(
                            "[{}] L2[{l1_idx}/{l2_idx}] stamp to missing file {bfi}",
                            img.name
                        )),
                        Some(owner) => {
                            // zero-flagged stamps carry no offset; data
                            // and payload ranges must exist in the owner
                            let cs = owner.geom().cluster_size();
                            let end = e.data_offset()
                                + if e.is_compressed() {
                                    compressed_stored_len(&e, cs)
                                } else {
                                    1
                                };
                            if !e.is_zero_cluster() && end > owner.file_len() {
                                total.errors.push(format!(
                                    "[{}] L2[{l1_idx}/{l2_idx}] stamp offset beyond \
                                     '{}' EOF",
                                    img.name, owner.name
                                ));
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(total)
}

/// What one repair pass fixed (all zero on an already-clean image,
/// except possibly a tail truncation of freed clusters).
#[derive(Clone, Copy, Debug, Default)]
pub struct RepairReport {
    /// Dangling L1 pointers (misaligned / beyond EOF) cleared.
    pub l1_cleared: u64,
    /// Dangling L2 mappings cleared (local beyond EOF, garbage offsets,
    /// remote stamps that cannot be valid).
    pub entries_cleared: u64,
    /// Local entries whose `backing_file_index` stamp was rewritten to
    /// the owning file's index (torn restamp passes).
    pub stamps_fixed: u64,
    /// Invalid refcount-table slots cleared.
    pub reftable_cleared: u64,
    /// Refcounts rewritten to match the L1/L2 walk.
    pub refcounts_rewritten: u64,
    /// Clusters that had a refcount but no reference (leaks reclaimed).
    pub leaks_reclaimed: u64,
    /// Orphaned clusters cut off the end of the file.
    pub tail_clusters_truncated: u64,
}

impl RepairReport {
    pub fn changed(&self) -> bool {
        self.l1_cleared
            + self.entries_cleared
            + self.stamps_fixed
            + self.reftable_cleared
            + self.refcounts_rewritten
            + self.leaks_reclaimed
            + self.tail_clusters_truncated
            > 0
    }

    fn absorb(&mut self, other: RepairReport) {
        self.l1_cleared += other.l1_cleared;
        self.entries_cleared += other.entries_cleared;
        self.stamps_fixed += other.stamps_fixed;
        self.reftable_cleared += other.reftable_cleared;
        self.refcounts_rewritten += other.refcounts_rewritten;
        self.leaks_reclaimed += other.leaks_reclaimed;
        self.tail_clusters_truncated += other.tail_clusters_truncated;
    }
}

/// Repair a single image in place so [`check_image`] passes clean:
/// clear dangling table pointers, fix torn stamps, rebuild every
/// refcount from the L1/L2 walk, truncate the orphaned tail, and
/// rebuild the in-RAM allocator from the repaired state.
pub fn repair_image(img: &Image) -> Result<RepairReport> {
    let geom = *img.geom();
    let cs = geom.cluster_size();
    let own = img.chain_index();
    let meta_end = geom.first_free_cluster() * cs;
    let mut rep = RepairReport::default();
    let file_len = img.file_len();

    // 1. L1 pointers: a valid L2 table lives on a cluster boundary in
    //    the allocatable region of this file.
    for l1_idx in 0..geom.l1_entries() {
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            continue;
        }
        if l2_off % cs != 0 || l2_off >= file_len || l2_off < meta_end {
            img.clear_l1_entry(l1_idx)?;
            rep.l1_cleared += 1;
        }
    }

    // 2. L2 entries: clear dangling local mappings (the data write that
    //    should have preceded them is beyond EOF, so it never happened),
    //    restamp local entries torn mid-restamp, clear impossible
    //    remote stamps. The repaired tables are simultaneously the
    //    ground truth for the refcount rebuild (one metadata pass, not
    //    two): `expected` accumulates while each table is in memory.
    let per_l2 = geom.entries_per_l2();
    let per_block = geom.refcounts_per_block();
    let mut expected: HashMap<u64, u16> = HashMap::new();
    for c in 0..geom.first_free_cluster() {
        expected.insert(c, 1);
    }
    for l1_idx in 0..geom.l1_entries() {
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            continue;
        }
        let mut entries = img.read_l2_slice(l2_off, 0, per_l2)?;
        let mut dirty = false;
        for raw in entries.iter_mut() {
            let e = L2Entry(*raw);
            if e.is_zero() {
                continue;
            }
            // data range this entry claims in this file (nothing for
            // zero-flagged entries, the unit-rounded payload for
            // compressed ones, a whole cluster for plain data)
            let off = e.data_offset();
            let end = if e.is_zero_cluster() {
                0
            } else if e.is_compressed() {
                off + compressed_stored_len(&e, cs)
            } else {
                off + 1
            };
            let out = if !e.descriptor_valid()
                || (e.descriptor() == 0 && off % cs != 0)
            {
                rep.entries_cleared += 1;
                L2Entry::ZERO
            } else if e.is_allocated_here() {
                if end != 0 && (end > file_len || off < meta_end) {
                    rep.entries_cleared += 1;
                    L2Entry::ZERO
                } else {
                    match e.bfi() {
                        Some(b) if b != own => {
                            rep.stamps_fixed += 1;
                            // restamp, keeping the offset word (and thus
                            // the zero/compressed descriptor) intact
                            L2Entry::local(e.host_offset(), Some(own))
                        }
                        _ => continue,
                    }
                }
            } else {
                match e.bfi() {
                    Some(b) if b >= own => {
                        rep.entries_cleared += 1;
                        L2Entry::ZERO
                    }
                    _ => continue,
                }
            };
            *raw = out.raw();
            dirty = true;
        }
        if dirty {
            img.write_l2_slice(l2_off, 0, &entries)?;
        }
        *expected.entry(l2_off / cs).or_default() += 1;
        for raw in &entries {
            let e = L2Entry(*raw);
            if e.is_allocated_here() && !e.is_zero_cluster() {
                *expected.entry(e.data_offset() / cs).or_default() += 1;
            }
        }
    }

    // 3. Refcount table: drop slots that cannot point at a block.
    let nslots = geom.reftable_clusters() * cs / ENTRY_SIZE;
    let mut table = img.read_l2_slice(geom.reftable_offset(), 0, nslots)?;
    for (slot_idx, slot) in table.iter_mut().enumerate() {
        if *slot == 0 {
            continue;
        }
        if *slot % cs != 0 || *slot >= file_len {
            write_u64(
                img.backend().as_ref(),
                geom.reftable_offset() + slot_idx as u64 * ENTRY_SIZE,
                0,
            )?;
            *slot = 0;
            rep.reftable_cleared += 1;
        }
    }

    // 4. The surviving refcount blocks are referenced by the table.
    for &slot in table.iter().filter(|&&s| s != 0) {
        *expected.entry(slot / cs).or_default() += 1;
    }

    // 5. Every expected cluster needs a covering refcount block. Under
    //    the refcount-before-reference rule the block always exists;
    //    if a cleared slot orphaned one, grow replacement blocks at the
    //    end of the file (they join `expected` and are filled in 6).
    let mut end_cluster = div_ceil(file_len, cs);
    loop {
        let missing: Vec<u64> = expected
            .keys()
            .map(|c| c / per_block)
            .filter(|&bi| table.get(bi as usize) == Some(&0))
            .collect();
        if missing.is_empty() {
            break;
        }
        let mut grown = false;
        for block_idx in missing {
            if table.get(block_idx as usize) != Some(&0) {
                continue;
            }
            let block_off = end_cluster * cs;
            img.backend().truncate_to(block_off + cs)?;
            write_u64(
                img.backend().as_ref(),
                geom.reftable_offset() + block_idx * ENTRY_SIZE,
                block_off,
            )?;
            table[block_idx as usize] = block_off;
            expected.insert(end_cluster, 1);
            end_cluster += 1;
            grown = true;
        }
        if !grown {
            break;
        }
    }

    // 6. Rewrite refcounts wholesale from the expected map.
    let mut block_buf = vec![0u8; cs as usize];
    for (block_idx, &block_off) in table.iter().enumerate() {
        if block_off == 0 {
            continue;
        }
        img.backend().read_at(&mut block_buf, block_off)?;
        let base = block_idx as u64 * per_block;
        let mut dirty = false;
        for i in 0..per_block {
            let a = (i * 2) as usize;
            let stored =
                u16::from_le_bytes([block_buf[a], block_buf[a + 1]]);
            let want = expected.get(&(base + i)).copied().unwrap_or(0);
            if stored != want {
                rep.refcounts_rewritten += 1;
                if want == 0 && stored > 0 {
                    rep.leaks_reclaimed += 1;
                }
                block_buf[(i * 2) as usize..(i * 2 + 2) as usize]
                    .copy_from_slice(&want.to_le_bytes());
                dirty = true;
            }
        }
        if dirty {
            img.backend().write_at(&block_buf, block_off)?;
        }
    }

    // 7. Orphaned tail: nothing referenced lives past the last expected
    //    cluster — give the space back.
    let last_used = expected.keys().copied().max().unwrap_or(0);
    let want_len = (last_used + 1) * cs;
    let cur_len = img.file_len();
    if cur_len > want_len {
        let got = img.backend().shrink_to(want_len)?;
        rep.tail_clusters_truncated = div_ceil(cur_len.saturating_sub(got), cs);
    }

    // 8. The allocator must see the repaired refcounts, not its scan of
    //    the crashed state.
    img.reset_allocator()?;
    Ok(rep)
}

/// Repair a whole chain: every image individually, then clear remote
/// stamps whose cross-file target no longer exists (the owner's repair
/// may have truncated it). Re-run [`check_chain`] afterwards to verify.
pub fn repair_chain(chain: &Chain) -> Result<RepairReport> {
    let mut total = RepairReport::default();
    for img in chain.images() {
        total.absorb(repair_image(img)?);
    }
    for img in chain.images() {
        let geom = *img.geom();
        let per_l2 = geom.entries_per_l2();
        for l1_idx in 0..geom.l1_entries() {
            let l2_off = img.l1_entry(l1_idx);
            if l2_off == 0 {
                continue;
            }
            let mut entries = img.read_l2_slice(l2_off, 0, per_l2)?;
            let mut dirty = false;
            for raw in entries.iter_mut() {
                let e = L2Entry(*raw);
                let Some(bfi) = e.bfi() else { continue };
                if e.is_allocated_here() {
                    continue;
                }
                let valid = chain.get(bfi).is_some_and(|owner| {
                    e.is_zero_cluster() || {
                        let cs = owner.geom().cluster_size();
                        let end = e.data_offset()
                            + if e.is_compressed() {
                                compressed_stored_len(&e, cs)
                            } else {
                                1
                            };
                        end <= owner.file_len()
                    }
                });
                if !valid {
                    *raw = L2Entry::ZERO.raw();
                    dirty = true;
                    total.entries_cleared += 1;
                }
            }
            if dirty {
                img.write_l2_slice(l2_off, 0, &entries)?;
            }
        }
    }
    Ok(total)
}

/// On-disk bytes of a compressed entry's payload (unit-rounded), 0 for
/// anything else.
fn compressed_stored_len(e: &L2Entry, cluster_size: u64) -> u64 {
    match e.loc() {
        ClusterLoc::Compressed { units, .. } => units * (cluster_size >> 7),
        _ => 0,
    }
}

fn stored_refcount(img: &Image, cluster: u64) -> Result<u16> {
    let geom = *img.geom();
    let block_idx = cluster / geom.refcounts_per_block();
    let slot = geom.reftable_offset() + block_idx * 8;
    let block_off = crate::storage::backend::read_u64(img.backend().as_ref(), slot)?;
    if block_off == 0 {
        return Ok(0);
    }
    let idx = cluster % geom.refcounts_per_block();
    let mut b = [0u8; 2];
    img.backend().read_at(&mut b, block_off + idx * 2)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::snapshot;
    use crate::storage::node::StorageNode;
    use std::sync::Arc;

    fn setup() -> (Arc<StorageNode>, Chain) {
        let node = StorageNode::new("s", VirtClock::new(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let chain = Chain::new(Arc::new(img)).unwrap();
        (node, chain)
    }

    fn write_cluster(chain: &Chain, vc: u64) {
        let img = chain.active();
        let off = img.alloc_data_cluster().unwrap();
        img.write_data(off, 0, &[7u8; 16]).unwrap();
        img.set_l2_entry(vc, L2Entry::local(off, Some(img.chain_index())))
            .unwrap();
    }

    #[test]
    fn fresh_image_is_clean() {
        let (_n, chain) = setup();
        let r = check_image(chain.active()).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
        assert!(r.ok_clusters >= 3); // header + L1 + reftable
    }

    #[test]
    fn populated_chain_is_clean() {
        let (node, mut chain) = setup();
        for vc in 0..10 {
            write_cluster(&chain, vc);
        }
        snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        for vc in 5..15 {
            write_cluster(&chain, vc);
        }
        let r = check_chain(&chain).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
    }

    #[test]
    fn detects_bad_stamp() {
        let (_n, chain) = setup();
        // a base image (own index 0) cannot hold remote stamps
        chain
            .active()
            .set_l2_entry(0, L2Entry::remote(1 << 16, 3))
            .unwrap();
        let r = check_chain(&chain).unwrap();
        assert!(!r.is_clean());
    }

    #[test]
    fn detects_misaligned_entry() {
        let (_n, chain) = setup();
        chain
            .active()
            .set_l2_entry(0, L2Entry::local((1 << 16) + 5, Some(0)))
            .unwrap();
        let r = check_image(chain.active()).unwrap();
        assert!(!r.is_clean());
    }

    #[test]
    fn repair_clears_dangling_mapping_and_reclaims_leak() {
        let (_n, chain) = setup();
        write_cluster(&chain, 0);
        let img = chain.active();
        // dangling mapping far beyond EOF: the ordered-write rules mean
        // its data write never happened, so clearing it is lossless
        img.set_l2_entry(9, L2Entry::local(1 << 40, Some(0))).unwrap();
        // leaked cluster: refcounted, referenced by nothing
        img.alloc_data_cluster().unwrap();
        assert!(!check_image(img).unwrap().is_clean());
        let rep = repair_image(img).unwrap();
        assert!(rep.entries_cleared >= 1, "{rep:?}");
        assert!(rep.leaks_reclaimed >= 1, "{rep:?}");
        let after = check_image(img).unwrap();
        assert!(after.is_clean(), "{:?}", after.errors);
        assert_eq!(after.leaked_clusters, 0);
        // the good mapping survived
        assert!(img.l2_entry(0).unwrap().is_allocated_here());
        assert_eq!(img.l2_entry(9).unwrap(), L2Entry::ZERO);
    }

    #[test]
    fn repair_fixes_torn_stamp_without_losing_data() {
        let (_n, chain) = setup();
        write_cluster(&chain, 3);
        let img = chain.active();
        let off = img.l2_entry(3).unwrap().host_offset();
        // a crash mid-restamp left a local entry with a foreign index
        img.set_l2_entry(3, L2Entry::local(off, Some(7))).unwrap();
        assert!(!check_image(img).unwrap().is_clean());
        let rep = repair_image(img).unwrap();
        assert_eq!(rep.stamps_fixed, 1, "{rep:?}");
        assert!(check_image(img).unwrap().is_clean());
        let e = img.l2_entry(3).unwrap();
        assert_eq!(e.host_offset(), off, "data mapping preserved");
        assert_eq!(e.bfi(), Some(0));
        let mut buf = [0u8; 16];
        img.read_data(off, 0, &mut buf).unwrap();
        assert_eq!(buf, [7u8; 16]);
    }

    #[test]
    fn repair_truncates_orphaned_tail() {
        let (_n, chain) = setup();
        write_cluster(&chain, 0);
        let img = chain.active();
        // orphaned tail: clusters allocated (refcount + truncate) whose
        // mappings were lost in the crash
        img.alloc_data_cluster().unwrap();
        img.alloc_data_cluster().unwrap();
        let before = img.file_len();
        let rep = repair_image(img).unwrap();
        assert_eq!(rep.tail_clusters_truncated, 2, "{rep:?}");
        assert!(img.file_len() < before);
        assert!(check_image(img).unwrap().is_clean());
        // reclaimed space is handed out again (allocator rebuilt)
        let off = img.alloc_data_cluster().unwrap();
        assert!(off < before, "truncated tail is reusable");
    }

    #[test]
    fn repair_chain_clears_dangling_cross_file_stamp() {
        let (node, mut chain) = setup();
        write_cluster(&chain, 0);
        snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        // remote stamp pointing past the base's EOF
        chain
            .active()
            .set_l2_entry(8, L2Entry::remote(1 << 40, 0))
            .unwrap();
        assert!(!check_chain(&chain).unwrap().is_clean());
        let rep = repair_chain(&chain).unwrap();
        assert!(rep.entries_cleared >= 1, "{rep:?}");
        let after = check_chain(&chain).unwrap();
        assert!(after.is_clean(), "{:?}", after.errors);
        // the valid inherited stamp still resolves
        assert_eq!(chain.active().l2_entry(0).unwrap().bfi(), Some(0));
    }

    #[test]
    fn repair_is_a_noop_on_a_clean_image() {
        let (_n, chain) = setup();
        for vc in 0..5 {
            write_cluster(&chain, vc);
        }
        let rep = repair_image(chain.active()).unwrap();
        assert!(!rep.changed(), "{rep:?}");
        assert!(check_image(chain.active()).unwrap().is_clean());
    }

    #[test]
    fn flagged_entries_survive_check_and_repair() {
        // regression: zero-flagged and compressed entries used to look
        // like dangling/misaligned mappings and repair cleared them
        let (node, mut chain) = setup();
        write_cluster(&chain, 0);
        let img = chain.active();
        img.set_l2_entry(1, L2Entry::zero_cluster(Some(0))).unwrap();
        let cs = img.geom().cluster_size() as usize;
        let mut data = vec![0u8; cs];
        data[..100].fill(3);
        let word = img.write_compressed(&data).unwrap().expect("compressible");
        img.set_l2_entry(2, L2Entry::local(word, Some(0))).unwrap();
        let r = check_image(img).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
        let rep = repair_image(img).unwrap();
        assert!(!rep.changed(), "flagged entries treated as dangling: {rep:?}");
        let ez = img.l2_entry(1).unwrap();
        assert!(ez.is_zero_cluster() && ez.is_allocated_here());
        let ec = img.l2_entry(2).unwrap();
        assert!(ec.is_compressed());
        // payload still decodes after repair rebuilt the refcounts
        let ClusterLoc::Compressed { off, units } = ec.loc() else {
            panic!("{ec:?}")
        };
        let mut out = vec![0u8; cs];
        img.read_compressed(off, units, &mut out).unwrap();
        assert_eq!(out, data);
        // flags survive the snapshot copy + whole-chain check too
        snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        let r = check_chain(&chain).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
        assert!(chain.active().l2_entry(1).unwrap().is_zero_cluster());
        assert!(chain.active().l2_entry(2).unwrap().is_compressed());
    }

    #[test]
    fn allocator_reopen_keeps_compressed_payload_cluster() {
        // regression companion: Allocator::from_file must see the
        // payload's host cluster as referenced (refcount >= 1), not
        // hand it out again after a reopen
        let (_n, chain) = setup();
        let img = chain.active();
        let cs = img.geom().cluster_size() as usize;
        let data = vec![9u8; cs];
        let word = img.write_compressed(&data).unwrap().unwrap();
        let e = L2Entry::local(word, Some(0));
        img.set_l2_entry(0, e).unwrap();
        img.reset_allocator().unwrap();
        let payload_cluster = e.data_offset() / cs as u64;
        for _ in 0..8 {
            let off = img.alloc_data_cluster().unwrap();
            assert_ne!(
                off / cs as u64,
                payload_cluster,
                "payload cluster handed out as free after reopen"
            );
        }
        let mut out = vec![0u8; cs];
        let ClusterLoc::Compressed { off, units } = e.loc() else { panic!() };
        img.read_compressed(off, units, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn repair_clears_garbage_descriptor_bits() {
        let (_n, chain) = setup();
        let img = chain.active();
        // low bits set but not a valid descriptor: corruption
        img.set_l2_entry(0, L2Entry::local((1 << 16) + 4, Some(0))).unwrap();
        assert!(!check_image(img).unwrap().is_clean());
        let rep = repair_image(img).unwrap();
        assert!(rep.entries_cleared >= 1, "{rep:?}");
        assert_eq!(img.l2_entry(0).unwrap(), L2Entry::ZERO);
        assert!(check_image(img).unwrap().is_clean());
    }

    #[test]
    fn stream_merge_leaves_clean_chain() {
        let (node, mut chain) = setup();
        write_cluster(&chain, 0);
        snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        write_cluster(&chain, 1);
        snapshot::snapshot_sqemu(&mut chain, &node, "img-2").unwrap();
        write_cluster(&chain, 2);
        snapshot::snapshot_sqemu(&mut chain, &node, "img-3").unwrap();
        snapshot::stream_merge(&mut chain, 0, 2).unwrap();
        let r = check_chain(&chain).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
    }
}
