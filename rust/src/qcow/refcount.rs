//! Two-level refcounts (Qcow2-style): a preallocated refcount table of
//! pointers to on-demand refcount blocks of u16 counts, one per host
//! cluster. Cluster allocation bumps a fresh-space pointer and reuses an
//! in-memory free list (freed clusters are reusable within a session;
//! `qcheck` flags any leak on reopen, mirroring `qemu-img check`).

use super::layout::{Geometry, ENTRY_SIZE};
use crate::storage::backend::{read_u64, write_u64, Backend};
use anyhow::{bail, Result};

/// Mutable allocator state (kept under the image's allocation lock).
#[derive(Debug)]
pub struct Allocator {
    /// Next never-used cluster index (bump pointer).
    next_fresh: u64,
    /// Freed clusters available for reuse (session-local).
    free: Vec<u64>,
}

impl Allocator {
    /// Build allocator state for a fresh image.
    pub fn new(geom: &Geometry) -> Allocator {
        Allocator { next_fresh: geom.first_free_cluster(), free: Vec::new() }
    }

    /// Rebuild allocator state from an existing file: the bump pointer is
    /// the end of the file (freed-cluster reuse does not survive reopen).
    pub fn from_file(geom: &Geometry, file_len: u64) -> Allocator {
        let used = crate::util::div_ceil(file_len, geom.cluster_size());
        Allocator {
            next_fresh: used.max(geom.first_free_cluster()),
            free: Vec::new(),
        }
    }

    /// Allocate one host cluster; returns its byte offset. Updates the
    /// on-disk refcount structures through `backend`.
    pub fn alloc(&mut self, geom: &Geometry, backend: &dyn Backend) -> Result<u64> {
        self.alloc_tracked(geom, backend).map(|(off, _)| off)
    }

    /// Like [`Self::alloc`] but also reports whether the cluster was reused
    /// from the free list (and may therefore hold stale bytes the caller
    /// must zero).
    pub fn alloc_tracked(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
    ) -> Result<(u64, bool)> {
        let (cluster, reused) = match self.free.pop() {
            Some(c) => (c, true),
            None => {
                let c = self.next_fresh;
                self.next_fresh += 1;
                (c, false)
            }
        };
        self.set_refcount(geom, backend, cluster, 1)?;
        let off = cluster * geom.cluster_size();
        backend.truncate_to(off + geom.cluster_size())?;
        Ok((off, reused))
    }

    /// Release a host cluster by byte offset.
    pub fn free(&mut self, geom: &Geometry, backend: &dyn Backend, off: u64) -> Result<()> {
        let cluster = off / geom.cluster_size();
        let rc = self.refcount(geom, backend, cluster)?;
        if rc == 0 {
            bail!("double free of cluster {cluster}");
        }
        self.set_refcount(geom, backend, cluster, rc - 1)?;
        if rc == 1 {
            self.free.push(cluster);
        }
        Ok(())
    }

    /// Share a cluster (e.g. internal dedup); bumps its refcount.
    pub fn incref(&mut self, geom: &Geometry, backend: &dyn Backend, off: u64) -> Result<()> {
        let cluster = off / geom.cluster_size();
        let rc = self.refcount(geom, backend, cluster)?;
        self.set_refcount(geom, backend, cluster, rc + 1)
    }

    /// Read the refcount of a host cluster.
    pub fn refcount(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
        cluster: u64,
    ) -> Result<u16> {
        match self.block_offset(geom, backend, cluster, false)? {
            None => Ok(0),
            Some(block_off) => {
                let idx = cluster % geom.refcounts_per_block();
                let mut b = [0u8; 2];
                backend.read_at(&mut b, block_off + idx * 2)?;
                Ok(u16::from_le_bytes(b))
            }
        }
    }

    fn set_refcount(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
        cluster: u64,
        value: u16,
    ) -> Result<()> {
        let block_off = self
            .block_offset(geom, backend, cluster, true)?
            .expect("block allocated on demand");
        let idx = cluster % geom.refcounts_per_block();
        backend.write_at(&value.to_le_bytes(), block_off + idx * 2)
    }

    /// Offset of the refcount block covering `cluster`, allocating it
    /// (from fresh space) when `create` is set.
    fn block_offset(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
        cluster: u64,
        create: bool,
    ) -> Result<Option<u64>> {
        let block_idx = cluster / geom.refcounts_per_block();
        let table_slot = geom.reftable_offset() + block_idx * ENTRY_SIZE;
        if table_slot >= geom.reftable_offset()
            + geom.reftable_clusters() * geom.cluster_size()
        {
            bail!("refcount table exhausted (cluster {cluster})");
        }
        let existing = read_u64(backend, table_slot)?;
        if existing != 0 {
            return Ok(Some(existing));
        }
        if !create {
            return Ok(None);
        }
        // Allocate the block itself from fresh space; its own refcount may
        // live inside itself (self-describing, like Qcow2).
        let block_cluster = self.next_fresh;
        self.next_fresh += 1;
        let block_off = block_cluster * geom.cluster_size();
        backend.truncate_to(block_off + geom.cluster_size())?;
        write_u64(backend, table_slot, block_off)?;
        // zero the block then mark its own refcount
        let zeros = vec![0u8; geom.cluster_size() as usize];
        backend.write_at(&zeros, block_off)?;
        let own_block_idx = block_cluster / geom.refcounts_per_block();
        if own_block_idx == block_idx {
            let idx = block_cluster % geom.refcounts_per_block();
            backend.write_at(&1u16.to_le_bytes(), block_off + idx * 2)?;
        } else {
            // recurse: own refcount lives in another block
            self.set_refcount(geom, backend, block_cluster, 1)?;
        }
        Ok(Some(block_off))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qcow::layout::Geometry;
    use crate::storage::mem::MemBackend;

    fn setup() -> (Geometry, MemBackend, Allocator) {
        let geom = Geometry::new(16, 1 << 30).unwrap();
        let b = MemBackend::new();
        let a = Allocator::new(&geom);
        (geom, b, a)
    }

    #[test]
    fn alloc_distinct_counted() {
        let (geom, b, mut a) = setup();
        let o1 = a.alloc(&geom, &b).unwrap();
        let o2 = a.alloc(&geom, &b).unwrap();
        assert_ne!(o1, o2);
        assert_eq!(o1 % geom.cluster_size(), 0);
        assert_eq!(a.refcount(&geom, &b, o1 / geom.cluster_size()).unwrap(), 1);
        assert_eq!(a.refcount(&geom, &b, o2 / geom.cluster_size()).unwrap(), 1);
    }

    #[test]
    fn free_and_reuse() {
        let (geom, b, mut a) = setup();
        let o1 = a.alloc(&geom, &b).unwrap();
        a.free(&geom, &b, o1).unwrap();
        assert_eq!(a.refcount(&geom, &b, o1 / geom.cluster_size()).unwrap(), 0);
        let o2 = a.alloc(&geom, &b).unwrap();
        assert_eq!(o1, o2); // reused
    }

    #[test]
    fn double_free_rejected() {
        let (geom, b, mut a) = setup();
        let o = a.alloc(&geom, &b).unwrap();
        a.free(&geom, &b, o).unwrap();
        assert!(a.free(&geom, &b, o).is_err());
    }

    #[test]
    fn incref_shares() {
        let (geom, b, mut a) = setup();
        let o = a.alloc(&geom, &b).unwrap();
        a.incref(&geom, &b, o).unwrap();
        a.free(&geom, &b, o).unwrap();
        assert_eq!(a.refcount(&geom, &b, o / geom.cluster_size()).unwrap(), 1);
    }

    #[test]
    fn many_allocations_cross_blocks() {
        // force multiple refcount blocks with a small cluster size
        let geom = Geometry::new(9, 10 << 20).unwrap(); // 512 B clusters
        let b = MemBackend::new();
        let mut a = Allocator::new(&geom);
        let mut offs = std::collections::HashSet::new();
        for _ in 0..2000 {
            assert!(offs.insert(a.alloc(&geom, &b).unwrap()));
        }
        // every allocated cluster has refcount 1
        for &o in &offs {
            assert_eq!(a.refcount(&geom, &b, o / geom.cluster_size()).unwrap(), 1);
        }
    }

    #[test]
    fn reopen_state_is_safe() {
        let (geom, b, mut a) = setup();
        let o1 = a.alloc(&geom, &b).unwrap();
        let mut a2 = Allocator::from_file(&geom, b.len());
        let o2 = a2.alloc(&geom, &b).unwrap();
        assert!(o2 > o1, "fresh allocations never collide after reopen");
    }
}
