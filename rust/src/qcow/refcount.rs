//! Two-level refcounts (Qcow2-style): a preallocated refcount table of
//! pointers to on-demand refcount blocks of u16 counts, one per host
//! cluster. Cluster allocation bumps a fresh-space pointer and reuses an
//! in-memory free list (freed clusters are reusable within a session;
//! `qcheck` flags any leak on reopen, mirroring `qemu-img check`).

use super::layout::{Geometry, ENTRY_SIZE};
use crate::storage::backend::{read_u64, write_u64, Backend};
use anyhow::{bail, Result};

/// Mutable allocator state (kept under the image's allocation lock).
#[derive(Debug)]
pub struct Allocator {
    /// Next never-used cluster index (bump pointer).
    next_fresh: u64,
    /// Freed clusters available for reuse (session-local).
    free: Vec<u64>,
}

impl Allocator {
    /// Build allocator state for a fresh image.
    pub fn new(geom: &Geometry) -> Allocator {
        Allocator { next_fresh: geom.first_free_cluster(), free: Vec::new() }
    }

    /// Rebuild allocator state from an existing file by scanning its
    /// refcount blocks: the bump pointer stays conservatively at the end
    /// of the file (clusters whose refcount update was lost in a crash
    /// must never be handed out twice before repair), and every cluster
    /// below it with a zero refcount goes back on the free list — so
    /// clusters freed before a reopen are reusable instead of leaked
    /// forever.
    pub fn from_file(geom: &Geometry, backend: &dyn Backend) -> Result<Allocator> {
        let cs = geom.cluster_size();
        let file_len = backend.len();
        let next_fresh =
            crate::util::div_ceil(file_len, cs).max(geom.first_free_cluster());
        let mut free = Vec::new();
        // one read of the (small, preallocated) refcount table, then one
        // read per allocated refcount block
        let table_bytes = (geom.reftable_clusters() * cs) as usize;
        let mut table = vec![0u8; table_bytes];
        backend.read_at(&mut table, geom.reftable_offset())?;
        let per_block = geom.refcounts_per_block();
        let mut block = vec![0u8; cs as usize];
        for (block_idx, slot) in table.chunks_exact(8).enumerate() {
            let block_off = u64::from_le_bytes(slot.try_into().unwrap());
            if block_off == 0 || block_off % cs != 0 || block_off >= file_len {
                // absent (or corrupt — repair's business, not ours)
                continue;
            }
            backend.read_at(&mut block, block_off)?;
            let base = block_idx as u64 * per_block;
            for (i, rc) in block.chunks_exact(2).enumerate() {
                let cluster = base + i as u64;
                if cluster < geom.first_free_cluster() || cluster >= next_fresh {
                    continue;
                }
                if u16::from_le_bytes(rc.try_into().unwrap()) == 0 {
                    free.push(cluster);
                }
            }
        }
        Ok(Allocator { next_fresh, free })
    }

    /// Allocate one host cluster; returns its byte offset. Updates the
    /// on-disk refcount structures through `backend`.
    pub fn alloc(&mut self, geom: &Geometry, backend: &dyn Backend) -> Result<u64> {
        self.alloc_tracked(geom, backend).map(|(off, _)| off)
    }

    /// Like [`Self::alloc`] but also reports whether the cluster was reused
    /// from the free list (and may therefore hold stale bytes the caller
    /// must zero).
    pub fn alloc_tracked(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
    ) -> Result<(u64, bool)> {
        let (cluster, reused) = match self.free.pop() {
            Some(c) => (c, true),
            None => {
                let c = self.next_fresh;
                self.next_fresh += 1;
                (c, false)
            }
        };
        self.set_refcount(geom, backend, cluster, 1)?;
        let off = cluster * geom.cluster_size();
        backend.truncate_to(off + geom.cluster_size())?;
        Ok((off, reused))
    }

    /// Release a host cluster by byte offset.
    pub fn free(&mut self, geom: &Geometry, backend: &dyn Backend, off: u64) -> Result<()> {
        let cluster = off / geom.cluster_size();
        let rc = self.refcount(geom, backend, cluster)?;
        if rc == 0 {
            bail!("double free of cluster {cluster}");
        }
        self.set_refcount(geom, backend, cluster, rc - 1)?;
        if rc == 1 {
            self.free.push(cluster);
        }
        Ok(())
    }

    /// Share a cluster (e.g. internal dedup); bumps its refcount.
    pub fn incref(&mut self, geom: &Geometry, backend: &dyn Backend, off: u64) -> Result<()> {
        let cluster = off / geom.cluster_size();
        let rc = self.refcount(geom, backend, cluster)?;
        self.set_refcount(geom, backend, cluster, rc + 1)
    }

    /// Read the refcount of a host cluster.
    pub fn refcount(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
        cluster: u64,
    ) -> Result<u16> {
        match self.block_offset(geom, backend, cluster, false)? {
            None => Ok(0),
            Some(block_off) => {
                let idx = cluster % geom.refcounts_per_block();
                let mut b = [0u8; 2];
                backend.read_at(&mut b, block_off + idx * 2)?;
                Ok(u16::from_le_bytes(b))
            }
        }
    }

    fn set_refcount(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
        cluster: u64,
        value: u16,
    ) -> Result<()> {
        let block_off = self
            .block_offset(geom, backend, cluster, true)?
            .expect("block allocated on demand");
        let idx = cluster % geom.refcounts_per_block();
        backend.write_at(&value.to_le_bytes(), block_off + idx * 2)
    }

    /// Offset of the refcount block covering `cluster`, allocating it
    /// (from fresh space) when `create` is set.
    fn block_offset(
        &mut self,
        geom: &Geometry,
        backend: &dyn Backend,
        cluster: u64,
        create: bool,
    ) -> Result<Option<u64>> {
        let block_idx = cluster / geom.refcounts_per_block();
        let table_slot = geom.reftable_offset() + block_idx * ENTRY_SIZE;
        if table_slot >= geom.reftable_offset()
            + geom.reftable_clusters() * geom.cluster_size()
        {
            bail!("refcount table exhausted (cluster {cluster})");
        }
        let existing = read_u64(backend, table_slot)?;
        if existing != 0 {
            return Ok(Some(existing));
        }
        if !create {
            return Ok(None);
        }
        // Allocate the block itself from fresh space; its own refcount may
        // live inside itself (self-describing, like Qcow2).
        let block_cluster = self.next_fresh;
        self.next_fresh += 1;
        let block_off = block_cluster * geom.cluster_size();
        backend.truncate_to(block_off + geom.cluster_size())?;
        write_u64(backend, table_slot, block_off)?;
        // zero the block then mark its own refcount
        let zeros = vec![0u8; geom.cluster_size() as usize];
        backend.write_at(&zeros, block_off)?;
        let own_block_idx = block_cluster / geom.refcounts_per_block();
        if own_block_idx == block_idx {
            let idx = block_cluster % geom.refcounts_per_block();
            backend.write_at(&1u16.to_le_bytes(), block_off + idx * 2)?;
        } else {
            // recurse: own refcount lives in another block
            self.set_refcount(geom, backend, block_cluster, 1)?;
        }
        Ok(Some(block_off))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qcow::layout::Geometry;
    use crate::storage::mem::MemBackend;

    fn setup() -> (Geometry, MemBackend, Allocator) {
        let geom = Geometry::new(16, 1 << 30).unwrap();
        let b = MemBackend::new();
        let a = Allocator::new(&geom);
        (geom, b, a)
    }

    #[test]
    fn alloc_distinct_counted() {
        let (geom, b, mut a) = setup();
        let o1 = a.alloc(&geom, &b).unwrap();
        let o2 = a.alloc(&geom, &b).unwrap();
        assert_ne!(o1, o2);
        assert_eq!(o1 % geom.cluster_size(), 0);
        assert_eq!(a.refcount(&geom, &b, o1 / geom.cluster_size()).unwrap(), 1);
        assert_eq!(a.refcount(&geom, &b, o2 / geom.cluster_size()).unwrap(), 1);
    }

    #[test]
    fn free_and_reuse() {
        let (geom, b, mut a) = setup();
        let o1 = a.alloc(&geom, &b).unwrap();
        a.free(&geom, &b, o1).unwrap();
        assert_eq!(a.refcount(&geom, &b, o1 / geom.cluster_size()).unwrap(), 0);
        let o2 = a.alloc(&geom, &b).unwrap();
        assert_eq!(o1, o2); // reused
    }

    #[test]
    fn double_free_rejected() {
        let (geom, b, mut a) = setup();
        let o = a.alloc(&geom, &b).unwrap();
        a.free(&geom, &b, o).unwrap();
        assert!(a.free(&geom, &b, o).is_err());
    }

    #[test]
    fn incref_shares() {
        let (geom, b, mut a) = setup();
        let o = a.alloc(&geom, &b).unwrap();
        a.incref(&geom, &b, o).unwrap();
        a.free(&geom, &b, o).unwrap();
        assert_eq!(a.refcount(&geom, &b, o / geom.cluster_size()).unwrap(), 1);
    }

    #[test]
    fn many_allocations_cross_blocks() {
        // force multiple refcount blocks with a small cluster size
        let geom = Geometry::new(9, 10 << 20).unwrap(); // 512 B clusters
        let b = MemBackend::new();
        let mut a = Allocator::new(&geom);
        let mut offs = std::collections::HashSet::new();
        for _ in 0..2000 {
            assert!(offs.insert(a.alloc(&geom, &b).unwrap()));
        }
        // every allocated cluster has refcount 1
        for &o in &offs {
            assert_eq!(a.refcount(&geom, &b, o / geom.cluster_size()).unwrap(), 1);
        }
    }

    #[test]
    fn reopen_state_is_safe() {
        let (geom, b, mut a) = setup();
        let o1 = a.alloc(&geom, &b).unwrap();
        let mut a2 = Allocator::from_file(&geom, &b).unwrap();
        let o2 = a2.alloc(&geom, &b).unwrap();
        assert!(o2 > o1, "fresh allocations never collide after reopen");
    }

    #[test]
    fn freed_clusters_survive_reopen_as_reusable() {
        // regression: the old bump-pointer-from-file-length rebuild
        // leaked every cluster freed before a reopen, forever
        let (geom, b, mut a) = setup();
        let o1 = a.alloc(&geom, &b).unwrap();
        let o2 = a.alloc(&geom, &b).unwrap();
        let o3 = a.alloc(&geom, &b).unwrap();
        a.free(&geom, &b, o1).unwrap();
        a.free(&geom, &b, o3).unwrap();
        let mut a2 = Allocator::from_file(&geom, &b).unwrap();
        let r1 = a2.alloc(&geom, &b).unwrap();
        let r2 = a2.alloc(&geom, &b).unwrap();
        let mut reused = vec![r1, r2];
        reused.sort_unstable();
        let mut freed = vec![o1, o3];
        freed.sort_unstable();
        assert_eq!(reused, freed, "freed clusters are reused after reopen");
        // the next allocation after the free list drains is fresh space
        let r3 = a2.alloc(&geom, &b).unwrap();
        assert!(r3 > o3.max(o2), "bump pointer cleared the old file end");
        assert_eq!(a2.refcount(&geom, &b, r1 / geom.cluster_size()).unwrap(), 1);
    }
}
