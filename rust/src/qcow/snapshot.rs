//! Snapshot creation — vanilla (§2) and SQEMU (§5.4) — plus format
//! conversion and streaming (backing-file merge, §3/§4.1).

use super::chain::Chain;
use super::entry::{decode_offset, ClusterLoc, L2Entry};
use super::image::Image;
use super::layout::FEATURE_BFI;
use crate::storage::store::FileStore;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Vanilla snapshot: a new, *empty* active volume backing onto the old one
/// ("a new Qcow2 active volume is created, with very few information",
/// §5.4).
pub fn snapshot_vanilla(chain: &mut Chain, node: &dyn FileStore, new_name: &str) -> Result<()> {
    let old = Arc::clone(chain.active());
    let backend = node.create_file(new_name)?;
    let img = Image::create(
        new_name,
        backend,
        *old.geom(),
        old.flags() & !FEATURE_BFI,
        chain.len() as u16,
        Some(&old.name),
        old.data_mode(),
    )?;
    chain.push(Arc::new(img))
}

/// SQEMU snapshot (§5.4): the new active volume receives a full copy of
/// the old volume's L1+L2 tables, with every entry stamped with the
/// backing_file_index of the file actually owning the cluster. After this,
/// the active volume alone resolves any cluster in one step.
///
/// Requires the old active volume to be fully stamped (create chains with
/// [`snapshot_sqemu`] throughout, or run [`convert_to_sqemu`] first).
pub fn snapshot_sqemu(chain: &mut Chain, node: &dyn FileStore, new_name: &str) -> Result<()> {
    let old = Arc::clone(chain.active());
    if chain.len() > 1 && !old.has_bfi() {
        bail!(
            "active volume '{}' is not stamped; run convert_to_sqemu first",
            old.name
        );
    }
    let backend = node.create_file(new_name)?;
    // Crash ordering (DESIGN.md §10): the new volume is created WITHOUT
    // the BFI flag, tables are copied, and only then is the flag flipped
    // (an atomic, checksummed header rewrite). A crash mid-copy leaves an
    // unflagged image whose partial stamps drivers ignore — they fall
    // back to the chain walk — instead of a flagged image with an
    // incomplete index silently reading holes.
    let img = Image::create(
        new_name,
        backend,
        *old.geom(),
        old.flags() & !FEATURE_BFI,
        chain.len() as u16,
        Some(&old.name),
        old.data_mode(),
    )?;
    copy_stamped_tables(&old, &img)?;
    img.set_feature_bfi()?;
    chain.push(Arc::new(img))
}

/// The §5.4 copy: for each old L1 entry, allocate the L2 table in the new
/// volume and copy the old table's content, rewriting entries as stamped
/// remote references.
fn copy_stamped_tables(old: &Image, new: &Image) -> Result<()> {
    let geom = *old.geom();
    let per_l2 = geom.entries_per_l2();
    let own = old.chain_index();
    for l1_idx in 0..geom.l1_entries() {
        let old_l2 = old.l1_entry(l1_idx);
        if old_l2 == 0 {
            continue;
        }
        // one read of the whole old table, one write of the new table
        let old_entries = old.read_l2_slice(old_l2, 0, per_l2)?;
        let mut new_entries = Vec::with_capacity(old_entries.len());
        for raw in old_entries {
            let e = L2Entry(raw);
            let out = match e.sqemu_view(own) {
                Some((bfi, off)) => L2Entry::remote(off, bfi),
                None => L2Entry::ZERO,
            };
            new_entries.push(out.raw());
        }
        let new_l2 = new.ensure_l2(l1_idx)?;
        new.write_l2_slice(new_l2, 0, &new_entries)?;
    }
    Ok(())
}

/// Convert a vanilla chain in place: walk the chain for every virtual
/// cluster and stamp the active volume's table with (bfi, offset) remote
/// references ("vanilla disk images can be easily converted to our
/// format", §5.1). Returns the number of entries stamped.
pub fn convert_to_sqemu(chain: &Chain) -> Result<u64> {
    let active = chain.active();
    let geom = *active.geom();
    let mut stamped = 0u64;
    for vc in 0..geom.num_vclusters() {
        if let Some((bfi, off)) = chain.resolve_walk(vc)? {
            let entry = if bfi == active.chain_index() {
                L2Entry::local(off, Some(bfi))
            } else {
                L2Entry::remote(off, bfi)
            };
            active.set_l2_entry(vc, entry)?;
            stamped += 1;
        }
    }
    Ok(stamped)
}

/// Streaming (§3, §4.1): merge the data of backing files
/// `[from, to]` (inclusive, by chain index) into file `to`, then drop the
/// merged predecessors from the chain. Data clusters owned by dropped
/// files are copied into `to`; entries already owned by newer files are
/// untouched. The rebuilt chain reuses the original file names for the
/// surviving suffix.
///
/// The dropped files are *not* deleted from their store here: a merged
/// predecessor may be a base image shared by other chains (§3, Fig 8),
/// and only the coordinator's [`crate::gc`] registry has the
/// cross-chain refcounts to know. Callers that own that knowledge hand
/// the drop set to GC (the coordinator does this automatically;
/// `sqemu gc run` is the offline-tool path).
///
/// Returns the number of cluster entries materialized in the target
/// (zero-flagged entries migrate without moving bytes but still count,
/// matching the streaming planner's per-entry estimate).
pub fn stream_merge(chain: &mut Chain, from: u16, to: u16) -> Result<u64> {
    if from > to || (to as usize) >= chain.len() {
        bail!("invalid stream range {from}..={to} for chain len {}", chain.len());
    }
    if from == to {
        return Ok(0);
    }
    let geom = *chain.active().geom();
    let target = Arc::clone(chain.get(to).expect("in range"));
    let mut copied = 0u64;
    for vc in 0..geom.num_vclusters() {
        // find the newest version within the merged window (stamps are
        // authoritative: a stamped entry says where the data lives, which
        // may be a different file — or, for a dedup share, a different
        // virtual cluster's storage)
        let mut owner: Option<(u16, u16, u64)> = None;
        for idx in (from..=to).rev() {
            let e = chain.get(idx).unwrap().l2_entry(vc)?;
            if let Some((bfi, word)) = e.sqemu_view(idx) {
                owner = Some((idx, bfi, word));
                break;
            }
        }
        let Some((_idx, bfi, word)) = owner else { continue };
        if bfi == to {
            continue; // the bytes are already stored in the target file
        }
        if bfi < from {
            // owned by a file below the window: that file survives the
            // merge, so a stamp to it stays valid and an unstamped walk
            // still reaches it — nothing to materialize
            continue;
        }
        // materialize the newest version in the target: zero clusters
        // stay deviceless, compressed data lands plain (payload packing
        // is per-file), plain data is copied
        let stamp = if target.has_bfi() { Some(target.chain_index()) } else { None };
        let src = chain.get(bfi).expect("stamp within chain");
        match decode_offset(word) {
            ClusterLoc::Zero => {
                // no bytes move, but the entry migrates — count it so the
                // streaming planner's per-entry estimate stays exact
                target.set_l2_entry(vc, L2Entry::zero_cluster(stamp))?;
                copied += 1;
            }
            ClusterLoc::Data(off) => {
                let new_off = target.alloc_data_cluster()?;
                let mut buf = vec![0u8; geom.cluster_size() as usize];
                src.read_data(off, 0, &mut buf)?;
                target.write_data(new_off, 0, &buf)?;
                target.set_l2_entry(vc, L2Entry::local(new_off, stamp))?;
                copied += 1;
            }
            ClusterLoc::Compressed { off, units } => {
                let new_off = target.alloc_data_cluster()?;
                let mut buf = vec![0u8; geom.cluster_size() as usize];
                src.read_compressed(off, units, &mut buf)?;
                target.write_data(new_off, 0, &buf)?;
                target.set_l2_entry(vc, L2Entry::local(new_off, stamp))?;
                copied += 1;
            }
        }
    }
    // Rebuild the chain as [0, from) + [to, len): merged predecessors are
    // dropped. Surviving files need their chain_index, backing link and
    // (for stamped images) their L2 bfi stamps remapped to the new
    // positions — an old index i maps to i (i < from), to `from`
    // (from <= i <= to, all merged into the target) or i - (to - from)
    // (i > to).
    let shift = to - from;
    let mut images: Vec<Arc<Image>> = Vec::new();
    for (i, img) in chain.images().iter().enumerate() {
        if i < from as usize || i >= to as usize {
            images.push(Arc::clone(img));
        }
    }
    for (new_idx, img) in images.iter().enumerate() {
        let backing = if new_idx == 0 {
            None
        } else {
            Some(images[new_idx - 1].name.clone())
        };
        img.update_header(new_idx as u16, backing.as_deref())?;
        if img.has_bfi() && new_idx >= from as usize {
            restamp_after_merge(img, &target, from, to, shift)?;
        }
    }
    chain.replace_images(images);
    Ok(copied)
}

/// Rewrite the bfi stamps of `img` after merging window `[from, to]` into
/// `target`:
/// * stamps below the window are untouched;
/// * stamps into the window are redirected to the cluster's new home in
///   `target` (looked up by virtual cluster — merged data moved, so the
///   stamped *offset* changes too);
/// * stamps above the window shift down by `shift`.
fn restamp_after_merge(
    img: &Image,
    target: &Image,
    from: u16,
    to: u16,
    shift: u16,
) -> Result<u64> {
    let geom = *img.geom();
    let per_l2 = geom.entries_per_l2();
    let is_target = std::ptr::eq(img as *const _, target as *const _)
        || img.name == target.name;
    let mut rewritten = 0u64;
    for l1_idx in 0..geom.l1_entries() {
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            continue;
        }
        let mut entries = img.read_l2_slice(l2_off, 0, per_l2)?;
        let mut dirty = false;
        for (l2_idx, raw) in entries.iter_mut().enumerate() {
            let e = L2Entry(*raw);
            let Some(bfi) = e.bfi() else { continue };
            let out = if bfi < from {
                continue;
            } else if bfi > to {
                let nb = bfi - shift;
                if e.is_allocated_here() {
                    L2Entry::local(e.host_offset(), Some(nb))
                } else {
                    L2Entry::remote(e.host_offset(), nb)
                }
            } else if is_target && e.is_allocated_here() {
                // the target's own data (pre-existing or just copied in):
                // only the index changes
                L2Entry::local(e.host_offset(), Some(from))
            } else {
                // stamp into the merged window: the data now lives in the
                // target; find its new offset by virtual cluster
                let vc = l1_idx * per_l2 + l2_idx as u64;
                match target.l2_entry(vc)?.vanilla_view() {
                    Some(off) => L2Entry::remote(off, from),
                    None => L2Entry::ZERO,
                }
            };
            if out != e {
                *raw = out.raw();
                dirty = true;
                rewritten += 1;
            }
        }
        if dirty {
            img.write_l2_slice(l2_off, 0, &entries)?;
        }
    }
    Ok(rewritten)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::layout::Geometry;
    use crate::storage::node::StorageNode;

    fn node() -> Arc<StorageNode> {
        StorageNode::new("s", VirtClock::new(), CostModel::default())
    }

    fn sq_base(node: &crate::storage::node::StorageNode) -> Chain {
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        Chain::new(Arc::new(img)).unwrap()
    }

    fn write_cluster(chain: &Chain, vc: u64, byte: u8) {
        let img = chain.active();
        let off = img.alloc_data_cluster().unwrap();
        let data = vec![byte; img.geom().cluster_size() as usize];
        img.write_data(off, 0, &data).unwrap();
        let stamp = if img.has_bfi() { Some(img.chain_index()) } else { None };
        img.set_l2_entry(vc, L2Entry::local(off, stamp)).unwrap();
    }

    #[test]
    fn sqemu_snapshot_copies_stamped_tables() {
        let node = node();
        let mut chain = sq_base(&node);
        write_cluster(&chain, 3, 0xAA);
        snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        // new active volume resolves cluster 3 without the chain
        let e = chain.active().l2_entry(3).unwrap();
        assert!(!e.is_allocated_here());
        assert_eq!(e.bfi(), Some(0));
        assert_eq!(
            e.host_offset(),
            chain.get(0).unwrap().l2_entry(3).unwrap().host_offset()
        );
    }

    #[test]
    fn sqemu_snapshot_chains_deepen_stamps() {
        let node = node();
        let mut chain = sq_base(&node);
        write_cluster(&chain, 1, 1);
        snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        write_cluster(&chain, 2, 2);
        snapshot_sqemu(&mut chain, &node, "img-2").unwrap();
        let active = chain.active();
        assert_eq!(active.l2_entry(1).unwrap().bfi(), Some(0));
        assert_eq!(active.l2_entry(2).unwrap().bfi(), Some(1));
        assert_eq!(active.l2_entry(3).unwrap(), L2Entry::ZERO);
    }

    #[test]
    fn vanilla_snapshot_is_empty() {
        let node = node();
        let mut chain = sq_base(&node);
        write_cluster(&chain, 1, 1);
        snapshot_vanilla(&mut chain, &node, "img-1").unwrap();
        assert_eq!(chain.active().l2_entry(1).unwrap(), L2Entry::ZERO);
        assert!(!chain.active().has_bfi());
        // but the chain still resolves through the backing file
        assert!(chain.resolve_walk(1).unwrap().is_some());
    }

    #[test]
    fn convert_stamps_vanilla_chain() {
        let node = node();
        let mut chain = sq_base(&node);
        write_cluster(&chain, 1, 1);
        snapshot_vanilla(&mut chain, &node, "img-1").unwrap();
        write_cluster(&chain, 2, 2);
        let stamped = convert_to_sqemu(&chain).unwrap();
        assert_eq!(stamped, 2);
        let active = chain.active();
        assert_eq!(active.l2_entry(1).unwrap().sqemu_view(1), Some((0, {
            chain.get(0).unwrap().l2_entry(1).unwrap().host_offset()
        })));
        assert_eq!(active.l2_entry(2).unwrap().bfi(), Some(1));
    }

    #[test]
    fn stream_merge_compacts_and_preserves_content() {
        let node = node();
        let mut chain = sq_base(&node);
        write_cluster(&chain, 0, 10);
        snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        write_cluster(&chain, 1, 11);
        snapshot_sqemu(&mut chain, &node, "img-2").unwrap();
        write_cluster(&chain, 2, 12);
        snapshot_sqemu(&mut chain, &node, "img-3").unwrap();
        write_cluster(&chain, 0, 99); // shadows cluster 0
        assert_eq!(chain.len(), 4);

        // merge files 0..=2 into file 2
        let copied = stream_merge(&mut chain, 0, 2).unwrap();
        assert_eq!(copied, 2); // clusters 0 and 1 copied into img-2
        assert_eq!(chain.len(), 2);
        // content: cluster 0 must still resolve to the newest write
        let (bfi, off) = chain.resolve_walk(0).unwrap().unwrap();
        assert_eq!(bfi as usize, chain.len() - 1);
        let mut buf = [0u8; 8];
        chain.get(bfi).unwrap().read_data(off, 0, &mut buf).unwrap();
        assert_eq!(buf, [99u8; 8]);
        // cluster 1 now lives in the merged target
        let (bfi1, off1) = chain.resolve_walk(1).unwrap().unwrap();
        let mut buf1 = [0u8; 8];
        chain.get(bfi1).unwrap().read_data(off1, 0, &mut buf1).unwrap();
        assert_eq!(buf1, [11u8; 8]);
    }
}
