//! Bit-exact host (pure Rust) implementations of the exported kernels.
//!
//! Two roles: (1) fallback so the library runs without compiled
//! artifacts, (2) differential oracle — `tests/runtime_artifacts.rs`
//! asserts PJRT results equal these for random inputs (the same contract
//! pytest enforces between the Pallas kernels and ref.py).

use super::UNALLOCATED;

/// SQEMU direct resolution: gather (bfi, off) per request plus the
/// per-file histogram (`hist_files` + 1 buckets; last = unallocated).
pub fn translate_direct(
    off: &[i32],
    bfi: &[i32],
    vbs: &[i32],
    hist_files: usize,
) -> (Vec<i32>, Vec<i32>, Vec<i64>) {
    let mut out_bfi = Vec::with_capacity(vbs.len());
    let mut out_off = Vec::with_capacity(vbs.len());
    let mut hist = vec![0i64; hist_files + 1];
    for &vb in vbs {
        let i = vb as usize;
        let (b, o) = if i < off.len() {
            (bfi[i], off[i])
        } else {
            (UNALLOCATED, UNALLOCATED)
        };
        out_bfi.push(b);
        out_off.push(o);
        let idx = if b == UNALLOCATED {
            hist_files
        } else {
            (b as usize).min(hist_files - 1)
        };
        hist[idx] += 1;
    }
    (out_bfi, out_off, hist)
}

/// vQemu chain walk: newest file holding the cluster wins.
pub fn translate_walk(tables: &[Vec<i32>], vbs: &[i32]) -> (Vec<i32>, Vec<i32>) {
    let mut out_bfi = vec![UNALLOCATED; vbs.len()];
    let mut out_off = vec![UNALLOCATED; vbs.len()];
    for (r, &vb) in vbs.iter().enumerate() {
        for j in (0..tables.len()).rev() {
            let t = tables[j].get(vb as usize).copied().unwrap_or(UNALLOCATED);
            if t != UNALLOCATED {
                out_bfi[r] = j as i32;
                out_off[r] = t;
                break;
            }
        }
    }
    (out_bfi, out_off)
}

/// §5.3 merge: entry b wins iff bfi_v <= bfi_b.
pub fn merge_l2(
    off_v: &[i32],
    bfi_v: &[i32],
    off_b: &[i32],
    bfi_b: &[i32],
) -> (Vec<i32>, Vec<i32>) {
    let mut off = Vec::with_capacity(off_v.len());
    let mut bfi = Vec::with_capacity(off_v.len());
    for i in 0..off_v.len() {
        if bfi_v[i] <= bfi_b[i] {
            off.push(off_b[i]);
            bfi.push(bfi_b[i]);
        } else {
            off.push(off_v[i]);
            bfi.push(bfi_v[i]);
        }
    }
    (off, bfi)
}

/// Fold tables oldest-first through [`merge_l2`].
pub fn stream_fold(offs: &[Vec<i32>], bfis: &[Vec<i32>]) -> (Vec<i32>, Vec<i32>) {
    let len = offs.first().map_or(0, |r| r.len());
    let mut off = vec![UNALLOCATED; len];
    let mut bfi = vec![UNALLOCATED; len];
    for (o_row, b_row) in offs.iter().zip(bfis) {
        let (no, nb) = merge_l2(&off, &bfi, o_row, b_row);
        off = no;
        bfi = nb;
    }
    (off, bfi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_gather_and_histogram() {
        let off = vec![10, UNALLOCATED, 30];
        let bfi = vec![0, UNALLOCATED, 2];
        let (b, o, h) = translate_direct(&off, &bfi, &[2, 0, 1, 2], 4);
        assert_eq!(b, vec![2, 0, UNALLOCATED, 2]);
        assert_eq!(o, vec![30, 10, UNALLOCATED, 30]);
        assert_eq!(h, vec![1, 0, 2, 0, 1]);
    }

    #[test]
    fn walk_newest_wins() {
        let tables = vec![
            vec![1, UNALLOCATED],
            vec![UNALLOCATED, 5],
            vec![9, UNALLOCATED],
        ];
        let (b, o) = translate_walk(&tables, &[0, 1]);
        assert_eq!(b, vec![2, 1]);
        assert_eq!(o, vec![9, 5]);
    }

    #[test]
    fn merge_rule_ties_to_b() {
        let (o, b) = merge_l2(&[1, 2], &[3, 3], &[9, 9], &[3, 2]);
        assert_eq!(o, vec![9, 2]);
        assert_eq!(b, vec![3, 3]);
    }

    #[test]
    fn stream_fold_equals_walk_flatten() {
        // folding per-file tables stamped with their index == chain walk
        let tables = vec![
            vec![10, 20, UNALLOCATED],
            vec![UNALLOCATED, 21, UNALLOCATED],
        ];
        let bfis: Vec<Vec<i32>> = (0..2)
            .map(|j| {
                tables[j]
                    .iter()
                    .map(|&t| if t == UNALLOCATED { UNALLOCATED } else { j as i32 })
                    .collect()
            })
            .collect();
        let (off, bfi) = stream_fold(&tables, &bfis);
        let (wb, wo) = translate_walk(&tables, &[0, 1, 2]);
        assert_eq!(off, wo);
        assert_eq!(bfi, wb);
    }
}
