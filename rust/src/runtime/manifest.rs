//! AOT manifest: shapes/dtypes of the exported artifacts
//! (`artifacts/manifest.json`, written by python/compile/aot.py).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    /// Input shapes (each a dim list; all int32 in this project).
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    /// Exported request batch size.
    pub batch: usize,
    /// Exported table width in clusters.
    pub clusters: usize,
    /// Exported chain-walk depth per call.
    pub chain: usize,
    /// Exported stream_fold depth.
    pub stream_depth: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let consts = j.get("constants").ok_or_else(|| anyhow!("no constants"))?;
        let get = |k: &str| -> Result<usize> {
            consts
                .get(k)
                .and_then(Json::as_u64)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("missing constant '{k}'"))
        };
        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("no artifacts"))?;
        for (name, meta) in arts {
            let file = meta
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact '{name}' missing file"))?
                .to_string();
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                meta.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact '{name}' missing {key}"))?
                    .iter()
                    .map(|io| {
                        io.get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("artifact '{name}' bad shape"))
                            .map(|dims| {
                                dims.iter()
                                    .filter_map(Json::as_u64)
                                    .map(|d| d as usize)
                                    .collect()
                            })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactMeta { file, inputs: shapes("inputs")?, outputs: shapes("outputs")? },
            );
        }
        Ok(Manifest {
            batch: get("batch")?,
            clusters: get("clusters")?,
            chain: get("chain")?,
            stream_depth: get("stream_depth")?,
            artifacts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "constants": {"batch": 256, "clusters": 8192, "chain": 32,
                    "stream_depth": 8, "unallocated": -1},
      "artifacts": {
        "merge_l2": {
          "file": "merge_l2.hlo.txt",
          "inputs": [{"shape": [8192], "dtype": "int32"},
                     {"shape": [8192], "dtype": "int32"},
                     {"shape": [8192], "dtype": "int32"},
                     {"shape": [8192], "dtype": "int32"}],
          "outputs": [{"shape": [8192], "dtype": "int32"},
                      {"shape": [8192], "dtype": "int32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.clusters, 8192);
        assert_eq!(m.chain, 32);
        assert_eq!(m.stream_depth, 8);
        let a = &m.artifacts["merge_l2"];
        assert_eq!(a.file, "merge_l2.hlo.txt");
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[0], vec![8192]);
        assert_eq!(a.outputs.len(), 2);
    }

    #[test]
    fn rejects_missing_constants() {
        assert!(Manifest::parse(r#"{"artifacts": {}}"#).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // integration sanity when `make artifacts` has run
        let p = std::path::Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.artifacts.contains_key("translate_direct"));
            assert!(m.artifacts.contains_key("stream_fold"));
        }
    }
}
