//! The AOT bridge: load the JAX/Pallas computations exported by
//! `python/compile/aot.py` (HLO text + manifest) and execute them on the
//! PJRT CPU client from the request path's *bulk* operations.
//!
//! Python never runs at request time: `make artifacts` compiles once; this
//! module loads `artifacts/*.hlo.txt` with
//! `HloModuleProto::from_text_file`, compiles through the `xla` crate and
//! executes with concrete buffers. HLO *text* is the interchange format —
//! jax >= 0.5 emits 64-bit instruction ids in serialized protos which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Every kernel has a bit-exact host fallback ([`host`]) so the library
//! works without artifacts (and so tests can diff runtime vs host).

pub mod host;
pub mod manifest;
pub mod service;

use anyhow::{anyhow, bail, Context, Result};
use manifest::Manifest;
use std::collections::HashMap;
use std::path::Path;

/// The chain-frame sentinel shared with the kernels (ref.py UNALLOCATED).
pub const UNALLOCATED: i32 = -1;

/// Loaded PJRT executables for all exported artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

impl Runtime {
    /// Load and compile every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("load manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut exes = HashMap::new();
        for (name, art) in &manifest.artifacts {
            let path = dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path {path:?}"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime { client, exes, manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.exes.keys().cloned().collect();
        v.sort();
        v
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("no artifact '{name}'"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name} result: {e:?}"))?;
        // lowered with return_tuple=True
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// SQEMU bulk resolution (`translate_direct` artifact): resolve a
    /// batch of virtual clusters against the unified (off, bfi) table.
    /// `vbs` is chunked/padded to the exported batch size; tables larger
    /// than the exported `clusters` dimension are rejected (callers tile).
    ///
    /// Returns (bfi, off) per request plus the per-backing-file lookup
    /// histogram (index `chain` = unallocated).
    pub fn translate_direct(
        &self,
        off: &[i32],
        bfi: &[i32],
        vbs: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<i64>)> {
        let c = self.manifest.clusters;
        let b = self.manifest.batch;
        if off.len() != bfi.len() {
            bail!("off/bfi length mismatch");
        }
        if off.len() > c {
            bail!("table of {} clusters exceeds exported {c}", off.len());
        }
        let mut off_p = off.to_vec();
        let mut bfi_p = bfi.to_vec();
        off_p.resize(c, UNALLOCATED);
        bfi_p.resize(c, UNALLOCATED);
        let off_lit = xla::Literal::vec1(&off_p);
        let bfi_lit = xla::Literal::vec1(&bfi_p);

        let mut out_bfi = Vec::with_capacity(vbs.len());
        let mut out_off = Vec::with_capacity(vbs.len());
        let mut hist = vec![0i64; self.manifest.chain + 1];
        for chunk in vbs.chunks(b) {
            let mut v = chunk.to_vec();
            v.resize(b, 0); // padding resolves cluster 0; subtracted below
            let v_lit = xla::Literal::vec1(&v);
            let outs =
                self.run("translate_direct", &[off_lit.clone(), bfi_lit.clone(), v_lit])?;
            let rb = outs[0].to_vec::<i32>().map_err(wrap)?;
            let ro = outs[1].to_vec::<i32>().map_err(wrap)?;
            let rh = outs[2].to_vec::<i32>().map_err(wrap)?;
            out_bfi.extend_from_slice(&rb[..chunk.len()]);
            out_off.extend_from_slice(&ro[..chunk.len()]);
            for (i, &h) in rh.iter().enumerate() {
                hist[i] += h as i64;
            }
            // remove padding contributions from the histogram
            for &padded in &rb[chunk.len()..] {
                let idx = if padded == UNALLOCATED {
                    self.manifest.chain
                } else {
                    (padded as usize).min(self.manifest.chain - 1)
                };
                hist[idx] -= 1;
            }
        }
        Ok((out_bfi, out_off, hist))
    }

    /// vQemu bulk baseline (`translate_walk`): resolve against a stack of
    /// per-file tables. `tables` is `[n][c]`; n and c must not exceed the
    /// exported dims (callers tile/loop deeper chains).
    pub fn translate_walk(
        &self,
        tables: &[Vec<i32>],
        vbs: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        let c = self.manifest.clusters;
        let n = self.manifest.chain;
        let b = self.manifest.batch;
        if tables.len() > n {
            bail!("chain of {} exceeds exported depth {n}", tables.len());
        }
        let mut flat = Vec::with_capacity(n * c);
        for row in tables {
            if row.len() > c {
                bail!("table of {} clusters exceeds exported {c}", row.len());
            }
            flat.extend_from_slice(row);
            flat.resize(flat.len() + (c - row.len()), UNALLOCATED);
        }
        flat.resize(n * c, UNALLOCATED);
        let t_lit = xla::Literal::vec1(&flat)
            .reshape(&[n as i64, c as i64])
            .map_err(wrap)?;
        let mut out_bfi = Vec::with_capacity(vbs.len());
        let mut out_off = Vec::with_capacity(vbs.len());
        for chunk in vbs.chunks(b) {
            let mut v = chunk.to_vec();
            v.resize(b, 0);
            let v_lit = xla::Literal::vec1(&v);
            let outs = self.run("translate_walk", &[t_lit.clone(), v_lit])?;
            out_bfi
                .extend_from_slice(&outs[0].to_vec::<i32>().map_err(wrap)?[..chunk.len()]);
            out_off
                .extend_from_slice(&outs[1].to_vec::<i32>().map_err(wrap)?[..chunk.len()]);
        }
        Ok((out_bfi, out_off))
    }

    /// §5.3 merge (`merge_l2`): fold slice b into slice v under the
    /// precedence rule. Inputs padded to the exported cluster count.
    pub fn merge_l2(
        &self,
        off_v: &[i32],
        bfi_v: &[i32],
        off_b: &[i32],
        bfi_b: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        let c = self.manifest.clusters;
        let len = off_v.len();
        if len > c {
            bail!("table of {len} clusters exceeds exported {c}");
        }
        let pad = |xs: &[i32]| {
            let mut v = xs.to_vec();
            v.resize(c, UNALLOCATED);
            xla::Literal::vec1(&v)
        };
        let outs = self.run(
            "merge_l2",
            &[pad(off_v), pad(bfi_v), pad(off_b), pad(bfi_b)],
        )?;
        let mut off = outs[0].to_vec::<i32>().map_err(wrap)?;
        let mut bfi = outs[1].to_vec::<i32>().map_err(wrap)?;
        off.truncate(len);
        bfi.truncate(len);
        Ok((off, bfi))
    }

    /// Streaming planner (`stream_fold`): fold up to `stream_depth` tables
    /// (oldest first) into one flattened view in a single PJRT call.
    pub fn stream_fold(
        &self,
        offs: &[Vec<i32>],
        bfis: &[Vec<i32>],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        let c = self.manifest.clusters;
        let d = self.manifest.stream_depth;
        if offs.len() != bfis.len() {
            bail!("offs/bfis row count mismatch");
        }
        if offs.len() > d {
            bail!("{} tables exceed exported stream depth {d}", offs.len());
        }
        let len = offs.first().map_or(0, |r| r.len());
        let flatten = |rows: &[Vec<i32>]| -> Result<xla::Literal> {
            let mut flat = Vec::with_capacity(d * c);
            for row in rows {
                if row.len() != len {
                    bail!("ragged table rows");
                }
                flat.extend_from_slice(row);
                flat.resize(flat.len() + (c - row.len()), UNALLOCATED);
            }
            flat.resize(d * c, UNALLOCATED);
            xla::Literal::vec1(&flat)
                .reshape(&[d as i64, c as i64])
                .map_err(wrap)
        };
        let outs = self.run("stream_fold", &[flatten(offs)?, flatten(bfis)?])?;
        let mut off = outs[0].to_vec::<i32>().map_err(wrap)?;
        let mut bfi = outs[1].to_vec::<i32>().map_err(wrap)?;
        off.truncate(len);
        bfi.truncate(len);
        Ok((off, bfi))
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("{e:?}")
}

/// Default artifacts directory (overridable via `SQEMU_ARTIFACTS`).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("SQEMU_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| "artifacts".into())
}
