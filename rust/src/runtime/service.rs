//! RuntimeService: a single executor thread owning the PJRT client.
//!
//! The `xla` crate's client is `Rc`-based (not `Send`), but the
//! coordinator is multi-threaded — so all PJRT execution is funneled
//! through one dedicated thread (the "leader" executor), reached by a
//! cloneable, `Send + Sync` handle. Bulk callers block on a reply
//! channel; per-request driver paths never touch this.

use super::{host, Runtime};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, SyncSender};

type Reply<T> = SyncSender<Result<T>>;

enum Job {
    TranslateDirect {
        off: Vec<i32>,
        bfi: Vec<i32>,
        vbs: Vec<i32>,
        reply: Reply<(Vec<i32>, Vec<i32>, Vec<i64>)>,
    },
    TranslateWalk {
        tables: Vec<Vec<i32>>,
        vbs: Vec<i32>,
        reply: Reply<(Vec<i32>, Vec<i32>)>,
    },
    MergeL2 {
        off_v: Vec<i32>,
        bfi_v: Vec<i32>,
        off_b: Vec<i32>,
        bfi_b: Vec<i32>,
        reply: Reply<(Vec<i32>, Vec<i32>)>,
    },
    StreamFold {
        offs: Vec<Vec<i32>>,
        bfis: Vec<Vec<i32>>,
        reply: Reply<(Vec<i32>, Vec<i32>)>,
    },
    Shutdown,
}

/// Cloneable handle to the executor thread.
#[derive(Clone)]
pub struct RuntimeService {
    tx: SyncSender<Job>,
    /// Tiling limits copied out of the manifest.
    pub clusters: usize,
    pub chain: usize,
    pub stream_depth: usize,
    pub batch: usize,
}

impl RuntimeService {
    /// Spawn the executor; fails if the artifacts cannot be loaded.
    pub fn spawn(dir: impl Into<PathBuf>) -> Result<RuntimeService> {
        let dir = dir.into();
        let (tx, rx) = sync_channel::<Job>(16);
        let (init_tx, init_rx) = sync_channel::<Result<(usize, usize, usize, usize)>>(1);
        std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let m = &rt.manifest;
                        let _ = init_tx
                            .send(Ok((m.clusters, m.chain, m.stream_depth, m.batch)));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::TranslateDirect { off, bfi, vbs, reply } => {
                            let _ = reply.send(rt.translate_direct(&off, &bfi, &vbs));
                        }
                        Job::TranslateWalk { tables, vbs, reply } => {
                            let _ = reply.send(rt.translate_walk(&tables, &vbs));
                        }
                        Job::MergeL2 { off_v, bfi_v, off_b, bfi_b, reply } => {
                            let _ =
                                reply.send(rt.merge_l2(&off_v, &bfi_v, &off_b, &bfi_b));
                        }
                        Job::StreamFold { offs, bfis, reply } => {
                            let _ = reply.send(rt.stream_fold(&offs, &bfis));
                        }
                        Job::Shutdown => break,
                    }
                }
            })
            .expect("spawn pjrt executor");
        let (clusters, chain, stream_depth, batch) =
            init_rx.recv().map_err(|_| anyhow!("executor died during init"))??;
        Ok(RuntimeService { tx, clusters, chain, stream_depth, batch })
    }

    /// Spawn against the default artifacts dir, or None if unavailable.
    pub fn try_default() -> Option<RuntimeService> {
        RuntimeService::spawn(super::default_artifacts_dir()).ok()
    }

    fn call<T>(&self, build: impl FnOnce(Reply<T>) -> Job) -> Result<T> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(build(reply))
            .map_err(|_| anyhow!("pjrt executor gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt executor gone"))?
    }

    pub fn translate_direct(
        &self,
        off: &[i32],
        bfi: &[i32],
        vbs: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<i64>)> {
        self.call(|reply| Job::TranslateDirect {
            off: off.to_vec(),
            bfi: bfi.to_vec(),
            vbs: vbs.to_vec(),
            reply,
        })
    }

    pub fn translate_walk(
        &self,
        tables: &[Vec<i32>],
        vbs: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        self.call(|reply| Job::TranslateWalk {
            tables: tables.to_vec(),
            vbs: vbs.to_vec(),
            reply,
        })
    }

    pub fn merge_l2(
        &self,
        off_v: &[i32],
        bfi_v: &[i32],
        off_b: &[i32],
        bfi_b: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        self.call(|reply| Job::MergeL2 {
            off_v: off_v.to_vec(),
            bfi_v: bfi_v.to_vec(),
            off_b: off_b.to_vec(),
            bfi_b: bfi_b.to_vec(),
            reply,
        })
    }

    pub fn stream_fold(
        &self,
        offs: &[Vec<i32>],
        bfis: &[Vec<i32>],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        self.call(|reply| Job::StreamFold {
            offs: offs.to_vec(),
            bfis: bfis.to_vec(),
            reply,
        })
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Job::Shutdown);
    }
}

/// Differential helper: run a translate through the service and the host
/// kernels, asserting equality (used by tests and `sqemu selftest`).
pub fn verify_service(svc: &RuntimeService) -> Result<()> {
    let off = vec![5, -1, 7, 9];
    let bfi = vec![0, -1, 2, 1];
    let vbs = vec![0, 1, 2, 3, 2];
    let (gb, go, gh) = svc.translate_direct(&off, &bfi, &vbs)?;
    let (hb, ho, hh) = host::translate_direct(&off, &bfi, &vbs, svc.chain);
    if gb != hb || go != ho || gh != hh {
        anyhow::bail!("service/host mismatch");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_roundtrip_if_artifacts_present() {
        let Some(svc) = RuntimeService::try_default() else {
            eprintln!("SKIP: no artifacts");
            return;
        };
        verify_service(&svc).unwrap();
        // handle is cloneable and usable from other threads
        let svc2 = svc.clone();
        std::thread::spawn(move || verify_service(&svc2).unwrap())
            .join()
            .unwrap();
        svc.shutdown();
    }

    #[test]
    fn spawn_fails_on_missing_dir() {
        assert!(RuntimeService::spawn("/nonexistent-dir-xyz").is_err());
    }
}
