//! The byte-store trait all virtual-disk files are written through.

use anyhow::Result;
use std::sync::Arc;

/// A random-access byte store for one on-"disk" file. Implementations use
/// interior mutability so a file can be shared (`Arc<dyn Backend>`)
/// between a driver, the snapshot machinery and the coordinator.
pub trait Backend: Send + Sync {
    /// Read `buf.len()` bytes at `off`. Reads past `len()` zero-fill
    /// (sparse-file semantics, matching holes in Qcow2 files).
    fn read_at(&self, buf: &mut [u8], off: u64) -> Result<()>;

    /// Write at `off`, growing the file if needed.
    fn write_at(&self, data: &[u8], off: u64) -> Result<()>;

    /// Current file length in bytes.
    fn len(&self) -> u64;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Grow (never shrinks) to at least `len` bytes.
    fn truncate_to(&self, len: u64) -> Result<()>;

    /// Durability barrier: when this returns `Ok`, every write issued
    /// before the call is stable across a crash (power cut). The crash
    /// -consistency ordering rules (DESIGN.md §10) hang off this fence.
    /// Default: no-op, for backends that are exactly as durable as the
    /// process (pure in-memory stores have no weaker failure domain).
    fn flush(&self) -> Result<()> {
        Ok(())
    }

    /// Shrink the file to exactly `len` bytes, discarding the tail —
    /// `qcheck --repair`'s orphaned-tail reclaim. Returns the resulting
    /// length; backends that cannot shrink return their current length
    /// unchanged so callers can report honestly.
    fn shrink_to(&self, _len: u64) -> Result<u64> {
        Ok(self.len())
    }

    /// Scatter-gather read: fill every `(off, buf)` pair. The default
    /// loops `read_at` (one device I/O each); cost-charging backends
    /// override it to bill a run of physically contiguous pairs as ONE
    /// seek plus bandwidth for the total bytes (the vectored fast path).
    fn read_vectored(&self, iovs: &mut [(u64, &mut [u8])]) -> Result<()> {
        for iov in iovs.iter_mut() {
            self.read_at(iov.1, iov.0)?;
        }
        Ok(())
    }

    /// Gather write of every `(off, data)` pair; same contiguous-run
    /// billing contract as [`Backend::read_vectored`].
    fn write_vectored(&self, iovs: &[(u64, &[u8])]) -> Result<()> {
        for (off, data) in iovs {
            self.write_at(data, *off)?;
        }
        Ok(())
    }

    /// Charge the cost of touching `len` bytes at `off` *without* storing
    /// them — used by synthetic-data mode where benches skip materializing
    /// data clusters but must still pay their I/O time. Default: no cost
    /// (free backends have no clock).
    fn charge(&self, _off: u64, _len: u64) {}

    /// Physically stored bytes (for sparse accounting / Fig 19a).
    fn stored_bytes(&self) -> u64 {
        self.len()
    }

    /// Device I/O operations issued through this file so far, if the
    /// backend counts them (the timed backend does; a coalesced run
    /// counts once). Clock-less backends report 0 — counter-based tests
    /// and benches use this to assert how many seeks a path paid.
    fn device_ios(&self) -> u64 {
        0
    }

    /// The backend's notion of current time in ns, if it has one — the
    /// virtual node clock for [`super::timed::Timed`] files. Lets code
    /// holding only a file handle (e.g. the streaming orchestrator)
    /// measure the virtual duration of an operation. Clock-less backends
    /// report 0, making such measurements degrade to 0 rather than lie.
    fn now_ns(&self) -> u64 {
        0
    }
}

/// Shared handle to a backend.
pub type BackendRef = Arc<dyn Backend>;

/// Helpers common to all backends.
pub fn read_u64(b: &dyn Backend, off: u64) -> Result<u64> {
    let mut buf = [0u8; 8];
    b.read_at(&mut buf, off)?;
    Ok(u64::from_le_bytes(buf))
}

pub fn write_u64(b: &dyn Backend, off: u64, v: u64) -> Result<()> {
    b.write_at(&v.to_le_bytes(), off)
}
