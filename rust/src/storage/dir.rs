//! `DirStore`: a [`FileStore`] over a host directory with real files —
//! the CLI's image store (`sqemu create/snapshot/check` operate on actual
//! on-disk images that survive across invocations).

use super::backend::BackendRef;
use super::file::FileBackend;
use super::store::FileStore;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

pub struct DirStore {
    dir: PathBuf,
}

impl DirStore {
    pub fn new(dir: impl Into<PathBuf>) -> Result<DirStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create dir {dir:?}"))?;
        Ok(DirStore { dir })
    }

    fn path(&self, name: &str) -> Result<PathBuf> {
        if name.contains('/') || name.contains("..") {
            bail!("file name '{name}' must be a plain name");
        }
        Ok(self.dir.join(name))
    }
}

impl FileStore for DirStore {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        let path = self.path(name)?;
        if path.exists() {
            bail!("{path:?} already exists");
        }
        Ok(Arc::new(FileBackend::create(path)?))
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        Ok(Arc::new(FileBackend::open(self.path(name)?)?))
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        std::fs::remove_file(self.path(name)?).map_err(Into::into)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qcow::image::{DataMode, Image};
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::{snapshot, Chain};

    fn tmp() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sqemu-dirstore-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn chain_on_real_directory() {
        let dir = tmp();
        let store = DirStore::new(&dir).unwrap();
        let geom = Geometry::new(16, 16 << 20).unwrap();
        let b = store.create_file("base.sq").unwrap();
        let img =
            Image::create("base.sq", b, geom, FEATURE_BFI, 0, None, DataMode::Real)
                .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        snapshot::snapshot_sqemu(&mut chain, &store, "snap1.sq").unwrap();
        drop(chain);
        // reopen purely from the files on disk
        let chain = Chain::open(&store, "snap1.sq", DataMode::Real).unwrap();
        assert_eq!(chain.len(), 2);
        assert!(dir.join("base.sq").exists());
        assert!(dir.join("snap1.sq").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_path_tricks() {
        let store = DirStore::new(tmp()).unwrap();
        assert!(store.create_file("../evil").is_err());
        assert!(store.create_file("a/b").is_err());
    }
}
