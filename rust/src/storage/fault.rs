//! Fault injection: a power-cut / torn-write harness for crash-
//! consistency testing.
//!
//! A [`FaultInjector`] is the shared "power supply" of one simulated
//! storage node: every mutating operation on any file wrapped by a
//! [`FaultInjectingBackend`] is one *durable event* on a global event
//! counter. Arming the injector schedules a power cut at an arbitrary
//! event index; the cut event either persists nothing or — for torn
//! writes — only a prefix of its bytes, and every later operation fails
//! until [`FaultInjector::revive`] simulates the node coming back up.
//!
//! The crash model is the classic synchronous-disk one: completed writes
//! are durable, the cut write is lost or torn, nothing after it happens.
//! Real disks guarantee sector (512 B) atomicity, so the crash-everywhere
//! property test tears at sector granularity; the header tests tear at
//! arbitrary byte offsets to prove the checksummed double-slot header
//! survives even that.
//!
//! [`FaultStore`] is a [`FileStore`] of fault-wrapped in-memory files
//! sharing one injector — the whole-node harness the crash-recovery
//! suite (`tests/crash_recovery.rs`) replays workloads on.

use super::backend::{Backend, BackendRef};
use super::mem::MemBackend;
use super::store::FileStore;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Sector size assumed atomic by the ordering rules (DESIGN.md §10).
pub const SECTOR: u64 = 512;

/// What the injector decided for one durable event.
enum Outcome {
    /// Persist the operation in full.
    Proceed,
    /// Persist only the first `n` bytes of the write, then lose power.
    Tear(u64),
    /// Lose power before the operation persists anything.
    Cut,
}

/// Shared power supply for a set of fault-wrapped files.
#[derive(Debug)]
pub struct FaultInjector {
    /// Durable events observed so far (writes, truncates, creates,
    /// deletes — everything that mutates what a crash would preserve).
    events: AtomicU64,
    /// Event index at which power is lost; `u64::MAX` = disarmed.
    cut_at: AtomicU64,
    /// Bytes of the cut write to persist; `u64::MAX` = persist nothing.
    keep_bytes: AtomicU64,
    /// Power is out: every operation fails until `revive`.
    dead: AtomicBool,
}

impl FaultInjector {
    pub fn new() -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            events: AtomicU64::new(0),
            cut_at: AtomicU64::new(u64::MAX),
            keep_bytes: AtomicU64::new(u64::MAX),
            dead: AtomicBool::new(false),
        })
    }

    /// Schedule a power cut: the next `cut_after` events succeed, the
    /// event *at* index `events() + cut_after` is cut — persisting only
    /// `tear_keep` bytes if given (tearing applies to plain writes; any
    /// other cut event persists nothing).
    pub fn arm(&self, cut_after: u64, tear_keep: Option<u64>) {
        self.keep_bytes
            .store(tear_keep.unwrap_or(u64::MAX), Ordering::SeqCst);
        self.cut_at.store(
            self.events.load(Ordering::SeqCst).saturating_add(cut_after),
            Ordering::SeqCst,
        );
    }

    /// Cancel any scheduled cut (power stays on).
    pub fn disarm(&self) {
        self.cut_at.store(u64::MAX, Ordering::SeqCst);
    }

    /// Power the node back up (the recovery path reopens files next).
    pub fn revive(&self) {
        self.disarm();
        self.dead.store(false, Ordering::SeqCst);
    }

    /// Total durable events observed (the crash-everywhere loop bound).
    pub fn events(&self) -> u64 {
        self.events.load(Ordering::SeqCst)
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Account one non-write durable event (a file create or delete): it
    /// either fully happens or the power cut loses it entirely. Lets
    /// stores other than [`FaultStore`] (e.g. a fault-injected
    /// [`crate::storage::node::StorageNode`]) share the same event
    /// counter for their namespace mutations.
    pub fn durable_event(&self) -> Result<()> {
        match self.begin_event() {
            Outcome::Proceed => Ok(()),
            _ => Err(self.power_err()),
        }
    }

    fn power_err(&self) -> anyhow::Error {
        anyhow!("simulated power failure: storage node is down")
    }

    /// Account one durable event and decide its fate.
    fn begin_event(&self) -> Outcome {
        if self.is_dead() {
            return Outcome::Cut;
        }
        let idx = self.events.fetch_add(1, Ordering::SeqCst);
        if idx < self.cut_at.load(Ordering::SeqCst) {
            return Outcome::Proceed;
        }
        self.dead.store(true, Ordering::SeqCst);
        match self.keep_bytes.load(Ordering::SeqCst) {
            u64::MAX => Outcome::Cut,
            keep => Outcome::Tear(keep),
        }
    }
}

/// Backend decorator routing every mutation through a [`FaultInjector`].
pub struct FaultInjectingBackend {
    inner: BackendRef,
    injector: Arc<FaultInjector>,
}

impl FaultInjectingBackend {
    pub fn new(inner: BackendRef, injector: Arc<FaultInjector>) -> FaultInjectingBackend {
        FaultInjectingBackend { inner, injector }
    }

    pub fn injector(&self) -> &Arc<FaultInjector> {
        &self.injector
    }
}

impl Backend for FaultInjectingBackend {
    fn read_at(&self, buf: &mut [u8], off: u64) -> Result<()> {
        if self.injector.is_dead() {
            return Err(self.injector.power_err());
        }
        self.inner.read_at(buf, off)
    }

    fn write_at(&self, data: &[u8], off: u64) -> Result<()> {
        match self.injector.begin_event() {
            Outcome::Proceed => self.inner.write_at(data, off),
            Outcome::Tear(keep) => {
                let k = (keep as usize).min(data.len());
                if k > 0 {
                    self.inner.write_at(&data[..k], off)?;
                }
                Err(self.injector.power_err())
            }
            Outcome::Cut => Err(self.injector.power_err()),
        }
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn truncate_to(&self, len: u64) -> Result<()> {
        match self.injector.begin_event() {
            Outcome::Proceed => self.inner.truncate_to(len),
            _ => Err(self.injector.power_err()),
        }
    }

    fn flush(&self) -> Result<()> {
        // a barrier moves no new data: it fails when the node is down
        // but is not itself a cuttable durable event
        if self.injector.is_dead() {
            return Err(self.injector.power_err());
        }
        self.inner.flush()
    }

    fn shrink_to(&self, len: u64) -> Result<u64> {
        match self.injector.begin_event() {
            Outcome::Proceed => self.inner.shrink_to(len),
            _ => Err(self.injector.power_err()),
        }
    }

    fn stored_bytes(&self) -> u64 {
        self.inner.stored_bytes()
    }

    fn device_ios(&self) -> u64 {
        self.inner.device_ios()
    }

    fn now_ns(&self) -> u64 {
        self.inner.now_ns()
    }
}

/// A whole storage node under fault injection: named in-memory files,
/// each wrapped in a [`FaultInjectingBackend`] sharing one injector.
/// Files persist across "reboots" (`open_file` returns the same durable
/// state the crash left behind), which is what lets the crash-recovery
/// tests reopen and repair after a cut.
pub struct FaultStore {
    injector: Arc<FaultInjector>,
    files: Mutex<HashMap<String, BackendRef>>,
}

impl FaultStore {
    pub fn new(injector: Arc<FaultInjector>) -> FaultStore {
        FaultStore { injector, files: Mutex::new(HashMap::new()) }
    }

    pub fn injector(&self) -> &Arc<FaultInjector> {
        &self.injector
    }

    pub fn file_names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.files.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

impl FileStore for FaultStore {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        let mut files = self.files.lock().unwrap();
        if files.contains_key(name) {
            bail!("file '{name}' already exists");
        }
        // creating the directory entry is itself a durable event
        match self.injector.begin_event() {
            Outcome::Proceed => {}
            _ => return Err(self.injector.power_err()),
        }
        let backend: BackendRef = Arc::new(FaultInjectingBackend::new(
            Arc::new(MemBackend::new()),
            Arc::clone(&self.injector),
        ));
        files.insert(name.to_string(), Arc::clone(&backend));
        Ok(backend)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        if self.injector.is_dead() {
            return Err(self.injector.power_err());
        }
        self.files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no file '{name}'"))
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        match self.injector.begin_event() {
            Outcome::Proceed => {}
            _ => return Err(self.injector.power_err()),
        }
        match self.files.lock().unwrap().remove(name) {
            Some(_) => Ok(()),
            None => bail!("no file '{name}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wrapped() -> (Arc<FaultInjector>, FaultInjectingBackend) {
        let inj = FaultInjector::new();
        let b = FaultInjectingBackend::new(
            Arc::new(MemBackend::new()),
            Arc::clone(&inj),
        );
        (inj, b)
    }

    #[test]
    fn cut_after_n_writes_preserves_prefix() {
        let (inj, b) = wrapped();
        inj.arm(2, None);
        b.write_at(b"one", 0).unwrap();
        b.write_at(b"two", 10).unwrap();
        assert!(b.write_at(b"three", 20).is_err(), "third write is cut");
        assert!(b.write_at(b"four", 30).is_err(), "node stays down");
        assert!(b.read_at(&mut [0u8; 1], 0).is_err(), "reads fail too");
        inj.revive();
        let mut buf = [0u8; 3];
        b.read_at(&mut buf, 10).unwrap();
        assert_eq!(&buf, b"two");
        b.read_at(&mut buf, 20).unwrap();
        assert_eq!(buf, [0u8; 3], "the cut write left nothing behind");
    }

    #[test]
    fn torn_write_keeps_exact_prefix() {
        let (inj, b) = wrapped();
        b.write_at(&[0xAA; 8], 0).unwrap();
        inj.arm(0, Some(3));
        assert!(b.write_at(&[0xBB; 8], 0).is_err());
        inj.revive();
        let mut buf = [0u8; 8];
        b.read_at(&mut buf, 0).unwrap();
        assert_eq!(&buf[..3], &[0xBB; 3], "torn prefix persisted");
        assert_eq!(&buf[3..], &[0xAA; 5], "tail keeps the old bytes");
    }

    #[test]
    fn events_count_all_mutations() {
        let (inj, b) = wrapped();
        b.write_at(&[1], 0).unwrap();
        b.truncate_to(100).unwrap();
        b.flush().unwrap(); // a barrier is not a durable event
        assert_eq!(inj.events(), 2);
    }

    #[test]
    fn store_survives_reboot_with_durable_state() {
        let inj = FaultInjector::new();
        let store = FaultStore::new(Arc::clone(&inj));
        let f = store.create_file("disk").unwrap();
        f.write_at(b"durable", 0).unwrap();
        inj.arm(0, None);
        assert!(f.write_at(b"lost", 100).is_err());
        assert!(store.open_file("disk").is_err(), "node is down");
        inj.revive();
        let g = store.open_file("disk").unwrap();
        let mut buf = [0u8; 7];
        g.read_at(&mut buf, 0).unwrap();
        assert_eq!(&buf, b"durable");
        let mut tail = [9u8; 4];
        g.read_at(&mut tail, 100).unwrap();
        assert_eq!(tail, [0u8; 4], "the lost write never happened");
    }
}
