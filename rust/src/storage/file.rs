//! Real-file backend: the format is an actual on-disk file, so images
//! survive process restarts and the integration tests can verify the
//! on-disk layout byte-for-byte.

use super::backend::Backend;
use anyhow::{Context, Result};
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::Mutex;

/// Byte store over a host file (positional I/O, no seek state).
pub struct FileBackend {
    file: File,
    /// cached length; File::metadata on every call would dominate
    len: Mutex<u64>,
}

impl FileBackend {
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        Ok(FileBackend { file, len: Mutex::new(0) })
    }

    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("open {:?}", path.as_ref()))?;
        let len = file.metadata()?.len();
        Ok(FileBackend { file, len: Mutex::new(len) })
    }
}

impl Backend for FileBackend {
    fn read_at(&self, buf: &mut [u8], off: u64) -> Result<()> {
        let len = *self.len.lock().unwrap();
        // sparse semantics: reads past EOF zero-fill
        if off >= len {
            buf.fill(0);
            return Ok(());
        }
        let avail = (len - off).min(buf.len() as u64) as usize;
        self.file.read_exact_at(&mut buf[..avail], off)?;
        buf[avail..].fill(0);
        Ok(())
    }

    fn write_at(&self, data: &[u8], off: u64) -> Result<()> {
        self.file.write_all_at(data, off)?;
        let mut len = self.len.lock().unwrap();
        *len = (*len).max(off + data.len() as u64);
        Ok(())
    }

    fn len(&self) -> u64 {
        *self.len.lock().unwrap()
    }

    fn truncate_to(&self, new_len: u64) -> Result<()> {
        let mut len = self.len.lock().unwrap();
        if new_len > *len {
            self.file.set_len(new_len)?;
            *len = new_len;
        }
        Ok(())
    }

    fn flush(&self) -> Result<()> {
        self.file.sync_all().map_err(Into::into)
    }

    fn shrink_to(&self, new_len: u64) -> Result<u64> {
        let mut len = self.len.lock().unwrap();
        if new_len < *len {
            self.file.set_len(new_len)?;
            *len = new_len;
        }
        Ok(*len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sqemu-test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_and_reopen() {
        let p = tmp("file-roundtrip");
        {
            let b = FileBackend::create(&p).unwrap();
            b.write_at(b"persisted", 4096).unwrap();
        }
        let b = FileBackend::open(&p).unwrap();
        let mut buf = [0u8; 9];
        b.read_at(&mut buf, 4096).unwrap();
        assert_eq!(&buf, b"persisted");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn read_past_eof_zero_fills() {
        let p = tmp("file-eof");
        let b = FileBackend::create(&p).unwrap();
        b.write_at(&[7; 4], 0).unwrap();
        let mut buf = [9u8; 16];
        b.read_at(&mut buf, 0).unwrap();
        assert_eq!(&buf[..4], &[7; 4]);
        assert_eq!(&buf[4..], &[0; 12]);
        std::fs::remove_file(&p).unwrap();
    }
}
