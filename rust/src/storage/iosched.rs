//! Per-node I/O scheduler: cross-VM merge windows over one device.
//!
//! The `Timed` wrapper already bills a sorted iov list from ONE request
//! as one seek per physically contiguous run. What it cannot see is two
//! *different* VMs streaming adjacent extents of the same file — the
//! boot-storm shape, where a clone population reads a shared golden base
//! and the device could service the lot as one sequential pass.
//!
//! A shard executor opens a *merge window* on the nodes it serves for
//! the duration of one serving pass ([`MergeWindow`]). While at least
//! one window is open, every timed operation on the node's files is
//! billed through [`IoScheduler::try_bill`]: an extent that touches
//! (overlaps or abuts) an extent already serviced in the window pays
//! **no seek** — only bandwidth for its fresh bytes — because the
//! device is already positioned there; bytes another VM already
//! transferred in the window are not paid twice. With no window open,
//! `try_bill` declines and `Timed` falls back to its classic
//! per-request accounting, bit-identical to the pre-shard data plane.
//!
//! The scheduler also aggregates device-busy time and fresh transfer
//! bytes, which is how `fig25_fleet_scale` computes device-time
//! utilization against the cost model's theoretical bandwidth.

use crate::metrics::clock::CostModel;
use crate::util::lock_unpoisoned;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// What one billed operation cost under an open merge window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bill {
    /// 0 (merged into a serviced extent) or 1 (fresh device position).
    pub seeks: u64,
    /// Bytes actually transferred (extent minus already-serviced bytes).
    pub fresh: u64,
    /// Virtual ns the device was busy: `seeks * io_ns(0)` plus
    /// bandwidth time for the fresh bytes.
    pub ns: u64,
}

/// Extents serviced during the current merge window, per file.
#[derive(Default)]
struct WindowState {
    /// file id → sorted, disjoint `(start, end)` half-open intervals
    spans: HashMap<u64, Vec<(u64, u64)>>,
}

/// One storage node's device scheduler (owned by [`super::StorageNode`],
/// shared with every `Timed` file on the node).
pub struct IoScheduler {
    cost: CostModel,
    /// Open merge windows (shards currently in a serving pass). The
    /// window span state is shared: concurrent shards merge against
    /// each other's extents, which is the whole point.
    openers: AtomicUsize,
    state: Mutex<WindowState>,
    next_file_id: AtomicU64,
    /// Virtual ns the device spent busy under merge windows.
    busy_ns: AtomicU64,
    /// Bytes transferred under merge windows (deduplicated).
    fresh_bytes: AtomicU64,
    /// Seeks billed under merge windows.
    seeks: AtomicU64,
    /// Seeks avoided because the extent touched a serviced one.
    merged_seeks: AtomicU64,
    /// Merge windows opened over the node's lifetime.
    window_opens: AtomicU64,
}

/// Point-in-time counters for reporting (CLI, fig25).
#[derive(Debug, Clone, Copy, Default)]
pub struct IoSchedSnapshot {
    pub busy_ns: u64,
    pub fresh_bytes: u64,
    pub seeks: u64,
    pub merged_seeks: u64,
    pub window_opens: u64,
}

impl IoScheduler {
    pub fn new(cost: CostModel) -> Arc<IoScheduler> {
        Arc::new(IoScheduler {
            cost,
            openers: AtomicUsize::new(0),
            state: Mutex::new(WindowState::default()),
            next_file_id: AtomicU64::new(1),
            busy_ns: AtomicU64::new(0),
            fresh_bytes: AtomicU64::new(0),
            seeks: AtomicU64::new(0),
            merged_seeks: AtomicU64::new(0),
            window_opens: AtomicU64::new(0),
        })
    }

    /// Assign an id to a file opened on this node's device (each `Timed`
    /// registers once at creation).
    pub fn register_file(&self) -> u64 {
        self.next_file_id.fetch_add(1, Relaxed)
    }

    /// True while at least one shard holds a merge window open.
    pub fn window_open(&self) -> bool {
        self.openers.load(Relaxed) > 0
    }

    fn open_window(&self) {
        if self.openers.fetch_add(1, Relaxed) == 0 {
            self.window_opens.fetch_add(1, Relaxed);
        }
    }

    fn close_window(&self) {
        if self.openers.fetch_sub(1, Relaxed) == 1 {
            // last opener: the device moves on, forget serviced extents
            lock_unpoisoned(&self.state).spans.clear();
        }
    }

    /// Bill `[off, off+len)` on `file` against the open merge window.
    /// Returns `None` when no window is open — the caller must then use
    /// its classic (bit-identical to pre-shard) accounting.
    pub fn try_bill(&self, file: u64, off: u64, len: u64) -> Option<Bill> {
        if !self.window_open() {
            return None;
        }
        let (start, end) = (off, off.saturating_add(len));
        let mut st = lock_unpoisoned(&self.state);
        let ivs = st.spans.entry(file).or_default();

        // find every serviced interval touching (overlapping or
        // abutting) the new extent; they merge into one
        let mut covered = 0u64;
        let mut touched = false;
        let (mut lo, mut hi) = (start, end);
        let mut keep = Vec::with_capacity(ivs.len() + 1);
        for &(a, b) in ivs.iter() {
            if b < start || a > end {
                keep.push((a, b));
            } else {
                touched = true;
                covered += b.min(end).saturating_sub(a.max(start));
                lo = lo.min(a);
                hi = hi.max(b);
            }
        }
        keep.push((lo, hi));
        keep.sort_unstable();
        *ivs = keep;
        drop(st);

        let fresh = len.saturating_sub(covered);
        let seeks = if touched { 0 } else { 1 };
        // io_ns(n) = T_L + T_D + n/bandwidth; the seek part is io_ns(0)
        let ns = seeks * self.cost.io_ns(0)
            + (self.cost.io_ns(fresh) - self.cost.io_ns(0));
        self.busy_ns.fetch_add(ns, Relaxed);
        self.fresh_bytes.fetch_add(fresh, Relaxed);
        self.seeks.fetch_add(seeks, Relaxed);
        self.merged_seeks.fetch_add(1 - seeks, Relaxed);
        Some(Bill { seeks, fresh, ns })
    }

    /// Account a durability barrier (flush) executed under an open
    /// window: one device round trip of busy time, no transfer. Returns
    /// false when no window is open.
    pub fn note_flush(&self) -> bool {
        if !self.window_open() {
            return false;
        }
        self.busy_ns.fetch_add(self.cost.io_ns(0), Relaxed);
        true
    }

    pub fn snapshot(&self) -> IoSchedSnapshot {
        IoSchedSnapshot {
            busy_ns: self.busy_ns.load(Relaxed),
            fresh_bytes: self.fresh_bytes.load(Relaxed),
            seeks: self.seeks.load(Relaxed),
            merged_seeks: self.merged_seeks.load(Relaxed),
            window_opens: self.window_opens.load(Relaxed),
        }
    }

    /// Fraction of device-busy time spent transferring bytes at the
    /// cost model's theoretical bandwidth (the fig25 gate). 1.0 when the
    /// device never ran under a window.
    pub fn utilization(&self) -> f64 {
        let busy = self.busy_ns.load(Relaxed);
        if busy == 0 {
            return 1.0;
        }
        let xfer = self.cost.io_ns(self.fresh_bytes.load(Relaxed))
            - self.cost.io_ns(0);
        xfer as f64 / busy as f64
    }
}

/// RAII guard: a shard's merge window over the node schedulers it is
/// about to serve. Open for one serving pass, dropped before job steps.
pub struct MergeWindow {
    scheds: Vec<Arc<IoScheduler>>,
}

impl MergeWindow {
    pub fn open(scheds: Vec<Arc<IoScheduler>>) -> MergeWindow {
        for s in &scheds {
            s.open_window();
        }
        MergeWindow { scheds }
    }
}

impl Drop for MergeWindow {
    fn drop(&mut self) {
        for s in &self.scheds {
            s.close_window();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Arc<IoScheduler> {
        IoScheduler::new(CostModel::default())
    }

    #[test]
    fn closed_window_declines() {
        let s = sched();
        let f = s.register_file();
        assert!(s.try_bill(f, 0, 4096).is_none());
        assert!(!s.note_flush());
        assert_eq!(s.snapshot().busy_ns, 0);
    }

    #[test]
    fn first_extent_pays_full_seek() {
        let s = sched();
        let f = s.register_file();
        let cost = CostModel::default();
        let _w = MergeWindow::open(vec![Arc::clone(&s)]);
        let b = s.try_bill(f, 0, 64 << 10).unwrap();
        assert_eq!(b.seeks, 1);
        assert_eq!(b.fresh, 64 << 10);
        assert_eq!(b.ns, cost.io_ns(64 << 10), "identical to classic billing");
    }

    #[test]
    fn adjacent_extent_from_another_vm_merges() {
        let s = sched();
        let f = s.register_file();
        let cost = CostModel::default();
        let _w = MergeWindow::open(vec![Arc::clone(&s)]);
        s.try_bill(f, 0, 64 << 10).unwrap();
        // second "VM" continues right where the first stopped
        let b = s.try_bill(f, 64 << 10, 64 << 10).unwrap();
        assert_eq!(b.seeks, 0, "no repositioning");
        assert_eq!(b.fresh, 64 << 10);
        assert_eq!(b.ns, cost.io_ns(64 << 10) - cost.io_ns(0));
        assert_eq!(s.snapshot().merged_seeks, 1);
    }

    #[test]
    fn overlap_bytes_are_not_paid_twice() {
        let s = sched();
        let f = s.register_file();
        let _w = MergeWindow::open(vec![Arc::clone(&s)]);
        s.try_bill(f, 0, 8192).unwrap();
        let b = s.try_bill(f, 4096, 8192).unwrap();
        assert_eq!(b.seeks, 0);
        assert_eq!(b.fresh, 4096, "only the tail is a fresh transfer");
        // fully covered extent costs nothing but queueing
        let b = s.try_bill(f, 0, 4096).unwrap();
        assert_eq!((b.seeks, b.fresh), (0, 0));
    }

    #[test]
    fn distant_extent_still_seeks() {
        let s = sched();
        let f = s.register_file();
        let _w = MergeWindow::open(vec![Arc::clone(&s)]);
        s.try_bill(f, 0, 4096).unwrap();
        let b = s.try_bill(f, 1 << 20, 4096).unwrap();
        assert_eq!(b.seeks, 1);
        assert_eq!(s.snapshot().seeks, 2);
    }

    #[test]
    fn files_do_not_merge_with_each_other() {
        let s = sched();
        let f1 = s.register_file();
        let f2 = s.register_file();
        let _w = MergeWindow::open(vec![Arc::clone(&s)]);
        s.try_bill(f1, 0, 4096).unwrap();
        let b = s.try_bill(f2, 4096, 4096).unwrap();
        assert_eq!(b.seeks, 1, "different file, different extent map");
    }

    #[test]
    fn window_close_forgets_extents() {
        let s = sched();
        let f = s.register_file();
        {
            let _w = MergeWindow::open(vec![Arc::clone(&s)]);
            s.try_bill(f, 0, 4096).unwrap();
        }
        assert!(s.try_bill(f, 4096, 4096).is_none(), "window closed");
        let _w = MergeWindow::open(vec![Arc::clone(&s)]);
        let b = s.try_bill(f, 4096, 4096).unwrap();
        assert_eq!(b.seeks, 1, "new window starts cold");
        assert_eq!(s.snapshot().window_opens, 2);
    }

    #[test]
    fn nested_windows_share_extent_state() {
        let s = sched();
        let f = s.register_file();
        let w1 = MergeWindow::open(vec![Arc::clone(&s)]);
        let w2 = MergeWindow::open(vec![Arc::clone(&s)]);
        s.try_bill(f, 0, 4096).unwrap();
        drop(w1);
        // w2 still open: extents survive
        let b = s.try_bill(f, 4096, 4096).unwrap();
        assert_eq!(b.seeks, 0, "concurrent shards merge against each other");
        drop(w2);
        assert!(!s.window_open());
    }

    #[test]
    fn utilization_reflects_seek_overhead() {
        let s = sched();
        let f = s.register_file();
        let _w = MergeWindow::open(vec![Arc::clone(&s)]);
        // one seek + 1 MiB sequential: utilization near 1
        s.try_bill(f, 0, 1 << 20).unwrap();
        assert!(s.utilization() > 0.9, "got {}", s.utilization());
        // many scattered 4 KiB extents drag it down
        for i in 0..64u64 {
            s.try_bill(f, (8 << 20) + i * (1 << 20), 4096).unwrap();
        }
        assert!(s.utilization() < 0.9, "got {}", s.utilization());
    }
}
