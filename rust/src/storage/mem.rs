//! Sparse in-memory backend: page-granular, stores only written pages.
//!
//! This is the default simulation store. Sparseness matters: a vanilla
//! 50 GiB image with a mostly-empty L2 index must not cost 50 GiB of host
//! RAM, and holes read back as zeros exactly like a sparse Qcow2 file on
//! ext4.

use super::backend::Backend;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::RwLock;

const PAGE_BITS: u32 = 16; // 64 KiB pages = default cluster size
const PAGE: usize = 1 << PAGE_BITS;

#[derive(Default)]
struct Inner {
    pages: HashMap<u64, Box<[u8; PAGE]>>,
    len: u64,
}

/// Sparse, thread-safe, in-memory byte store.
#[derive(Default)]
pub struct MemBackend {
    inner: RwLock<Inner>,
}

impl MemBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of physically materialized pages (sparse accounting).
    pub fn page_count(&self) -> usize {
        self.inner.read().unwrap().pages.len()
    }
}

impl Backend for MemBackend {
    fn read_at(&self, buf: &mut [u8], off: u64) -> Result<()> {
        let inner = self.inner.read().unwrap();
        let mut done = 0usize;
        while done < buf.len() {
            let pos = off + done as u64;
            let page_no = pos >> PAGE_BITS;
            let in_page = (pos & (PAGE as u64 - 1)) as usize;
            let n = (PAGE - in_page).min(buf.len() - done);
            match inner.pages.get(&page_no) {
                Some(p) => buf[done..done + n].copy_from_slice(&p[in_page..in_page + n]),
                None => buf[done..done + n].fill(0),
            }
            done += n;
        }
        Ok(())
    }

    fn write_at(&self, data: &[u8], off: u64) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        let mut done = 0usize;
        while done < data.len() {
            let pos = off + done as u64;
            let page_no = pos >> PAGE_BITS;
            let in_page = (pos & (PAGE as u64 - 1)) as usize;
            let n = (PAGE - in_page).min(data.len() - done);
            let page = inner
                .pages
                .entry(page_no)
                .or_insert_with(|| Box::new([0u8; PAGE]));
            page[in_page..in_page + n].copy_from_slice(&data[done..done + n]);
            done += n;
        }
        inner.len = inner.len.max(off + data.len() as u64);
        Ok(())
    }

    fn len(&self) -> u64 {
        self.inner.read().unwrap().len
    }

    fn truncate_to(&self, len: u64) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        inner.len = inner.len.max(len);
        Ok(())
    }

    fn shrink_to(&self, len: u64) -> Result<u64> {
        let mut inner = self.inner.write().unwrap();
        if len >= inner.len {
            return Ok(inner.len);
        }
        // drop pages entirely beyond the new length; zero the tail of a
        // straddling page so a later re-grow reads holes, not stale bytes
        let boundary_page = len >> PAGE_BITS;
        let in_page = (len & (PAGE as u64 - 1)) as usize;
        inner
            .pages
            .retain(|&page_no, _| page_no < boundary_page + u64::from(in_page > 0));
        if in_page > 0 {
            if let Some(p) = inner.pages.get_mut(&boundary_page) {
                p[in_page..].fill(0);
            }
        }
        inner.len = len;
        Ok(len)
    }

    fn stored_bytes(&self) -> u64 {
        (self.page_count() as u64) << PAGE_BITS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let b = MemBackend::new();
        b.write_at(b"hello world", 100).unwrap();
        let mut buf = [0u8; 11];
        b.read_at(&mut buf, 100).unwrap();
        assert_eq!(&buf, b"hello world");
        assert_eq!(b.len(), 111);
    }

    #[test]
    fn holes_read_zero() {
        let b = MemBackend::new();
        b.write_at(&[1, 2, 3], 1 << 20).unwrap();
        let mut buf = [9u8; 8];
        b.read_at(&mut buf, 0).unwrap();
        assert_eq!(buf, [0u8; 8]);
    }

    #[test]
    fn cross_page_write() {
        let b = MemBackend::new();
        let data: Vec<u8> = (0..200_000).map(|i| (i % 251) as u8).collect();
        b.write_at(&data, PAGE as u64 - 777).unwrap();
        let mut back = vec![0u8; data.len()];
        b.read_at(&mut back, PAGE as u64 - 777).unwrap();
        assert_eq!(back, data);
        assert!(b.page_count() >= 3);
    }

    #[test]
    fn sparse_accounting() {
        let b = MemBackend::new();
        b.write_at(&[1], 0).unwrap();
        b.write_at(&[1], 100 << 20).unwrap();
        assert_eq!(b.page_count(), 2); // not 1600 pages
        assert!(b.len() > 100 << 20);
    }

    #[test]
    fn shrink_drops_pages_and_zeroes_tail() {
        let b = MemBackend::new();
        let data = vec![7u8; 3 * PAGE];
        b.write_at(&data, 0).unwrap();
        assert_eq!(b.page_count(), 3);
        let new_len = b.shrink_to(PAGE as u64 + 100).unwrap();
        assert_eq!(new_len, PAGE as u64 + 100);
        assert_eq!(b.len(), PAGE as u64 + 100);
        assert_eq!(b.page_count(), 2, "pages beyond the cut dropped");
        // re-grow: the zapped region reads as zeros, not stale bytes
        b.truncate_to(3 * PAGE as u64).unwrap();
        let mut buf = [9u8; 8];
        b.read_at(&mut buf, PAGE as u64 + 200).unwrap();
        assert_eq!(buf, [0u8; 8]);
        b.read_at(&mut buf, 50).unwrap();
        assert_eq!(buf, [7u8; 8], "bytes below the cut survive");
    }

    #[test]
    fn truncate_grows_only() {
        let b = MemBackend::new();
        b.truncate_to(1000).unwrap();
        assert_eq!(b.len(), 1000);
        b.truncate_to(10).unwrap();
        assert_eq!(b.len(), 1000);
    }
}
