//! Storage substrate: where virtual-disk files physically live.
//!
//! The paper's testbed serves Qcow2 files from a storage node over NFS
//! (10 GbE, SATA SSD). Here a [`Backend`] is the byte store for one file;
//! [`timed::Timed`] wraps any backend with the Eq. 1 cost model charged to
//! a shared virtual clock; [`node::StorageNode`] groups the files of a
//! simulated storage server (the NFS stand-in).

pub mod backend;
pub mod dir;
pub mod fault;
pub mod file;
pub mod iosched;
pub mod mem;
pub mod node;
pub mod store;
pub mod timed;
pub mod watch;

pub use backend::{Backend, BackendRef};
pub use dir::DirStore;
pub use fault::{FaultInjectingBackend, FaultInjector, FaultStore};
pub use file::FileBackend;
pub use iosched::{IoSchedSnapshot, IoScheduler, MergeWindow};
pub use mem::MemBackend;
pub use node::StorageNode;
pub use store::FileStore;
pub use timed::Timed;
pub use watch::{Watched, WriteLog};
