//! Storage node: the simulated NFS server holding virtual-disk files.
//!
//! The paper's infrastructure spreads chains over storage nodes (a chain
//! can continue on another node when a disk grows past a physical disk —
//! §3/§4.1 thin provisioning). A `StorageNode` is a named collection of
//! files sharing one cost model and virtual clock; the coordinator's
//! placement module assigns backing files to nodes.

use super::backend::BackendRef;
use super::mem::MemBackend;
use super::timed::Timed;
use crate::metrics::clock::{CostModel, VirtClock};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A named storage server: files are created on it and served through its
/// latency model.
pub struct StorageNode {
    pub name: String,
    clock: Arc<VirtClock>,
    cost: CostModel,
    files: Mutex<HashMap<String, BackendRef>>,
    /// physical capacity in bytes (thin-provisioning trigger); u64::MAX =
    /// unlimited
    pub capacity: u64,
}

impl StorageNode {
    pub fn new(name: &str, clock: Arc<VirtClock>, cost: CostModel) -> Arc<Self> {
        Arc::new(StorageNode {
            name: name.to_string(),
            clock,
            cost,
            files: Mutex::new(HashMap::new()),
            capacity: u64::MAX,
        })
    }

    pub fn with_capacity(
        name: &str,
        clock: Arc<VirtClock>,
        cost: CostModel,
        capacity: u64,
    ) -> Arc<Self> {
        Arc::new(StorageNode {
            name: name.to_string(),
            clock,
            cost,
            files: Mutex::new(HashMap::new()),
            capacity,
        })
    }

    /// Create a new (timed, in-memory) file on this node.
    pub fn create_file(&self, name: &str) -> Result<BackendRef> {
        let mut files = self.files.lock().unwrap();
        if files.contains_key(name) {
            bail!("file '{name}' already exists on node '{}'", self.name);
        }
        let backend: BackendRef = Arc::new(Timed::new(
            MemBackend::new(),
            Arc::clone(&self.clock),
            self.cost,
        ));
        files.insert(name.to_string(), Arc::clone(&backend));
        Ok(backend)
    }

    pub fn open_file(&self, name: &str) -> Result<BackendRef> {
        self.files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no file '{name}' on node '{}'", self.name))
    }

    pub fn delete_file(&self, name: &str) -> Result<()> {
        match self.files.lock().unwrap().remove(name) {
            Some(_) => Ok(()),
            None => bail!("no file '{name}' on node '{}'", self.name),
        }
    }

    pub fn file_names(&self) -> Vec<String> {
        self.files.lock().unwrap().keys().cloned().collect()
    }

    /// Bytes physically stored across all files (capacity pressure).
    pub fn used_bytes(&self) -> u64 {
        self.files
            .lock()
            .unwrap()
            .values()
            .map(|f| f.stored_bytes())
            .sum()
    }

    /// Would adding `bytes` exceed this node's capacity?
    pub fn would_overflow(&self, bytes: u64) -> bool {
        self.used_bytes().saturating_add(bytes) > self.capacity
    }

    pub fn clock(&self) -> &Arc<VirtClock> {
        &self.clock
    }

    pub fn cost(&self) -> CostModel {
        self.cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> Arc<StorageNode> {
        StorageNode::new("s1", VirtClock::new(), CostModel::default())
    }

    #[test]
    fn create_open_delete() {
        let n = node();
        let f = n.create_file("disk-0").unwrap();
        f.write_at(b"x", 0).unwrap();
        let g = n.open_file("disk-0").unwrap();
        assert_eq!(g.len(), 1);
        assert!(n.create_file("disk-0").is_err());
        n.delete_file("disk-0").unwrap();
        assert!(n.open_file("disk-0").is_err());
    }

    #[test]
    fn io_charges_node_clock() {
        let n = node();
        let f = n.create_file("d").unwrap();
        let t0 = n.clock().now();
        f.write_at(&[0u8; 512], 0).unwrap();
        assert!(n.clock().now() > t0);
    }

    #[test]
    fn capacity_accounting() {
        let clock = VirtClock::new();
        let n = StorageNode::with_capacity("tiny", clock, CostModel::default(), 128 << 10);
        let f = n.create_file("d").unwrap();
        f.write_at(&[1u8; 64 << 10], 0).unwrap();
        assert!(!n.would_overflow(0));
        assert!(n.would_overflow(128 << 10));
    }
}
