//! Storage node: the simulated NFS server holding virtual-disk files.
//!
//! The paper's infrastructure spreads chains over storage nodes (a chain
//! can continue on another node when a disk grows past a physical disk —
//! §3/§4.1 thin provisioning). A `StorageNode` is a named collection of
//! files sharing one cost model and virtual clock; the coordinator's
//! placement module assigns backing files to nodes.

use super::backend::BackendRef;
use super::mem::MemBackend;
use super::timed::Timed;
use crate::metrics::clock::{CostModel, VirtClock};
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// A named storage server: files are created on it and served through its
/// latency model.
pub struct StorageNode {
    pub name: String,
    clock: Arc<VirtClock>,
    cost: CostModel,
    files: Mutex<HashMap<String, BackendRef>>,
    /// Files condemned by the GC registry (deferred delete): still
    /// physically present, but excluded from thin-provisioning pressure.
    condemned: Mutex<HashSet<String>>,
    /// Bytes returned by GC sweeps over this node's lifetime.
    reclaimed: AtomicU64,
    /// Files deleted by GC sweeps.
    gc_deletes: AtomicU64,
    /// physical capacity in bytes (thin-provisioning trigger); u64::MAX =
    /// unlimited
    pub capacity: u64,
}

impl StorageNode {
    pub fn new(name: &str, clock: Arc<VirtClock>, cost: CostModel) -> Arc<Self> {
        Self::with_capacity(name, clock, cost, u64::MAX)
    }

    pub fn with_capacity(
        name: &str,
        clock: Arc<VirtClock>,
        cost: CostModel,
        capacity: u64,
    ) -> Arc<Self> {
        Arc::new(StorageNode {
            name: name.to_string(),
            clock,
            cost,
            files: Mutex::new(HashMap::new()),
            condemned: Mutex::new(HashSet::new()),
            reclaimed: AtomicU64::new(0),
            gc_deletes: AtomicU64::new(0),
            capacity,
        })
    }

    /// Create a new (timed, in-memory) file on this node.
    pub fn create_file(&self, name: &str) -> Result<BackendRef> {
        let mut files = self.files.lock().unwrap();
        if files.contains_key(name) {
            bail!("file '{name}' already exists on node '{}'", self.name);
        }
        let backend: BackendRef = Arc::new(Timed::new(
            MemBackend::new(),
            Arc::clone(&self.clock),
            self.cost,
        ));
        files.insert(name.to_string(), Arc::clone(&backend));
        Ok(backend)
    }

    pub fn open_file(&self, name: &str) -> Result<BackendRef> {
        self.files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no file '{name}' on node '{}'", self.name))
    }

    pub fn delete_file(&self, name: &str) -> Result<()> {
        match self.files.lock().unwrap().remove(name) {
            Some(_) => {
                self.condemned.lock().unwrap().remove(name);
                Ok(())
            }
            None => bail!("no file '{name}' on node '{}'", self.name),
        }
    }

    pub fn file_names(&self) -> Vec<String> {
        self.files.lock().unwrap().keys().cloned().collect()
    }

    /// Bytes physically stored across all files (capacity pressure).
    pub fn used_bytes(&self) -> u64 {
        self.files
            .lock()
            .unwrap()
            .values()
            .map(|f| f.stored_bytes())
            .sum()
    }

    /// Mark `name` as condemned (GC deferred delete): its bytes stop
    /// counting against thin-provisioning pressure while the sweep is
    /// pending. No-op for files not on this node.
    pub fn mark_condemned(&self, name: &str) {
        let present = self.files.lock().unwrap().contains_key(name);
        if present {
            self.condemned.lock().unwrap().insert(name.to_string());
        }
    }

    /// Resurrect a condemned file (a chain re-referenced it before the
    /// sweep): its bytes count as pressure again.
    pub fn uncondemn(&self, name: &str) {
        self.condemned.lock().unwrap().remove(name);
    }

    /// Bytes held by condemned (pending-delete) files.
    pub fn condemned_bytes(&self) -> u64 {
        let files = self.files.lock().unwrap();
        self.condemned
            .lock()
            .unwrap()
            .iter()
            .filter_map(|n| files.get(n))
            .map(|f| f.stored_bytes())
            .sum()
    }

    /// Capacity pressure: stored bytes minus condemned bytes — what the
    /// placement layer sees. Condemned files are as good as deleted for
    /// thin provisioning; the GC sweep makes it physical.
    pub fn pressure_bytes(&self) -> u64 {
        let files = self.files.lock().unwrap();
        let condemned = self.condemned.lock().unwrap();
        files
            .iter()
            .filter(|(n, _)| !condemned.contains(n.as_str()))
            .map(|(_, f)| f.stored_bytes())
            .sum()
    }

    /// Account a GC deletion of `bytes` (called by the sweep).
    pub fn note_reclaimed(&self, bytes: u64) {
        self.reclaimed.fetch_add(bytes, Relaxed);
        self.gc_deletes.fetch_add(1, Relaxed);
    }

    /// Bytes reclaimed by GC over this node's lifetime.
    pub fn reclaimed_bytes(&self) -> u64 {
        self.reclaimed.load(Relaxed)
    }

    /// Files deleted by GC over this node's lifetime.
    pub fn gc_deletes(&self) -> u64 {
        self.gc_deletes.load(Relaxed)
    }

    /// Would adding `bytes` exceed this node's capacity? Condemned files
    /// do not count: their deletion is already scheduled.
    pub fn would_overflow(&self, bytes: u64) -> bool {
        self.pressure_bytes().saturating_add(bytes) > self.capacity
    }

    pub fn clock(&self) -> &Arc<VirtClock> {
        &self.clock
    }

    pub fn cost(&self) -> CostModel {
        self.cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> Arc<StorageNode> {
        StorageNode::new("s1", VirtClock::new(), CostModel::default())
    }

    #[test]
    fn create_open_delete() {
        let n = node();
        let f = n.create_file("disk-0").unwrap();
        f.write_at(b"x", 0).unwrap();
        let g = n.open_file("disk-0").unwrap();
        assert_eq!(g.len(), 1);
        assert!(n.create_file("disk-0").is_err());
        n.delete_file("disk-0").unwrap();
        assert!(n.open_file("disk-0").is_err());
    }

    #[test]
    fn io_charges_node_clock() {
        let n = node();
        let f = n.create_file("d").unwrap();
        let t0 = n.clock().now();
        f.write_at(&[0u8; 512], 0).unwrap();
        assert!(n.clock().now() > t0);
    }

    #[test]
    fn condemned_files_drop_out_of_pressure_not_usage() {
        let clock = VirtClock::new();
        let n = StorageNode::with_capacity("tiny", clock, CostModel::default(), 128 << 10);
        let f = n.create_file("d").unwrap();
        f.write_at(&[1u8; 96 << 10], 0).unwrap();
        assert!(n.would_overflow(64 << 10));
        n.mark_condemned("d");
        // physically still there, but no longer thin-provisioning pressure
        assert_eq!(n.used_bytes(), 96 << 10);
        assert_eq!(n.condemned_bytes(), 96 << 10);
        assert_eq!(n.pressure_bytes(), 0);
        assert!(!n.would_overflow(64 << 10));
        // resurrect: pressure returns
        n.uncondemn("d");
        assert!(n.would_overflow(64 << 10));
        // deleting clears the mark and the usage together
        n.mark_condemned("d");
        n.delete_file("d").unwrap();
        assert_eq!(n.used_bytes(), 0);
        assert_eq!(n.condemned_bytes(), 0);
    }

    #[test]
    fn reclaim_counters_accumulate() {
        let n = node();
        n.note_reclaimed(100);
        n.note_reclaimed(28);
        assert_eq!(n.reclaimed_bytes(), 128);
        assert_eq!(n.gc_deletes(), 2);
    }

    #[test]
    fn capacity_accounting() {
        let clock = VirtClock::new();
        let n = StorageNode::with_capacity("tiny", clock, CostModel::default(), 128 << 10);
        let f = n.create_file("d").unwrap();
        f.write_at(&[1u8; 64 << 10], 0).unwrap();
        assert!(!n.would_overflow(0));
        assert!(n.would_overflow(128 << 10));
    }
}
