//! Storage node: the simulated NFS server holding virtual-disk files.
//!
//! The paper's infrastructure spreads chains over storage nodes (a chain
//! can continue on another node when a disk grows past a physical disk —
//! §3/§4.1 thin provisioning). A `StorageNode` is a named collection of
//! files sharing one cost model and virtual clock; the coordinator's
//! placement module assigns backing files to nodes.
//!
//! Every file is served through a [`Watched`] wrapper so a live
//! migration can record the byte extents concurrent writers dirty
//! ([`StorageNode::watch`]), and the node tracks *capacity reservations*
//! ([`StorageNode::reserve`]) so thin-provisioning placement accounts
//! for in-flight migration copies before their bytes land.

use super::backend::BackendRef;
use super::fault::FaultInjector;
use super::iosched::IoScheduler;
use super::mem::MemBackend;
use super::timed::Timed;
use super::watch::{Watched, WriteLog};
use crate::metrics::clock::{CostModel, VirtClock};
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// One file on the node: its backend plus the write log the migration
/// mirror drains while copying the file off-node.
struct FileEntry {
    backend: BackendRef,
    log: Arc<WriteLog>,
}

/// A named storage server: files are created on it and served through its
/// latency model.
pub struct StorageNode {
    pub name: String,
    clock: Arc<VirtClock>,
    cost: CostModel,
    files: Mutex<HashMap<String, FileEntry>>,
    /// Files condemned by the GC registry (deferred delete): still
    /// physically present, but excluded from thin-provisioning pressure.
    condemned: Mutex<HashSet<String>>,
    /// Bytes reserved for in-flight migration copies: counted as
    /// pressure so placement and `would_overflow` see the recipient's
    /// true commitment before the bytes arrive.
    reserved: AtomicU64,
    /// Guest-addressable bytes mapped by the chains stored here, as of
    /// the coordinator's last capacity scan
    /// ([`crate::dedup::capacity::chain_logical_bytes`]). Physical usage
    /// ([`StorageNode::used_bytes`]) is what capacity decisions run on;
    /// this cache exists so reporting can show the multiplication factor
    /// (logical / physical) without rescanning every table.
    logical: AtomicU64,
    /// Directory listings served ([`StorageNode::file_names`]). The HA
    /// recovery tests assert log-replay recovery is O(leases) by pinning
    /// this counter: a replayed restart opens known files by name and
    /// never lists a node's namespace.
    list_ops: AtomicU64,
    /// Bytes returned by GC sweeps over this node's lifetime.
    reclaimed: AtomicU64,
    /// Files deleted by GC sweeps.
    gc_deletes: AtomicU64,
    /// Optional crash harness: when set, file creates/deletes count as
    /// durable events and every backend is fault-wrapped (the
    /// crash-injection suite's whole-node power-cut model).
    injector: Option<Arc<FaultInjector>>,
    /// The node device's I/O scheduler: shard executors open merge
    /// windows on it so contiguous extents from different VMs bill as
    /// one device pass (see [`super::iosched`]).
    sched: Arc<IoScheduler>,
    /// physical capacity in bytes (thin-provisioning trigger); u64::MAX =
    /// unlimited
    pub capacity: u64,
}

impl StorageNode {
    pub fn new(name: &str, clock: Arc<VirtClock>, cost: CostModel) -> Arc<Self> {
        Self::with_capacity(name, clock, cost, u64::MAX)
    }

    pub fn with_capacity(
        name: &str,
        clock: Arc<VirtClock>,
        cost: CostModel,
        capacity: u64,
    ) -> Arc<Self> {
        Self::build(name, clock, cost, capacity, None)
    }

    /// A node whose durable state is routed through `injector`: every
    /// backend write, file create and file delete is one durable event
    /// the crash harness may cut (see [`crate::storage::fault`]).
    pub fn with_fault_injection(
        name: &str,
        clock: Arc<VirtClock>,
        cost: CostModel,
        capacity: u64,
        injector: Arc<FaultInjector>,
    ) -> Arc<Self> {
        Self::build(name, clock, cost, capacity, Some(injector))
    }

    fn build(
        name: &str,
        clock: Arc<VirtClock>,
        cost: CostModel,
        capacity: u64,
        injector: Option<Arc<FaultInjector>>,
    ) -> Arc<Self> {
        Arc::new(StorageNode {
            name: name.to_string(),
            clock,
            cost,
            files: Mutex::new(HashMap::new()),
            condemned: Mutex::new(HashSet::new()),
            reserved: AtomicU64::new(0),
            list_ops: AtomicU64::new(0),
            logical: AtomicU64::new(0),
            reclaimed: AtomicU64::new(0),
            gc_deletes: AtomicU64::new(0),
            injector,
            sched: IoScheduler::new(cost),
            capacity,
        })
    }

    /// Create a new (timed, in-memory, watchable) file on this node.
    pub fn create_file(&self, name: &str) -> Result<BackendRef> {
        let mut files = self.files.lock().unwrap();
        if files.contains_key(name) {
            bail!("file '{name}' already exists on node '{}'", self.name);
        }
        // creating the directory entry is itself a durable event
        if let Some(inj) = &self.injector {
            inj.durable_event()?;
        }
        let timed: BackendRef = match &self.injector {
            Some(inj) => Arc::new(Timed::with_scheduler(
                super::fault::FaultInjectingBackend::new(
                    Arc::new(MemBackend::new()),
                    Arc::clone(inj),
                ),
                Arc::clone(&self.clock),
                self.cost,
                Arc::clone(&self.sched),
            )),
            None => Arc::new(Timed::with_scheduler(
                MemBackend::new(),
                Arc::clone(&self.clock),
                self.cost,
                Arc::clone(&self.sched),
            )),
        };
        let log = Arc::new(WriteLog::default());
        let backend: BackendRef = Arc::new(Watched::new(timed, Arc::clone(&log)));
        files.insert(name.to_string(), FileEntry { backend: Arc::clone(&backend), log });
        Ok(backend)
    }

    pub fn open_file(&self, name: &str) -> Result<BackendRef> {
        if let Some(inj) = &self.injector {
            if inj.is_dead() {
                bail!("simulated power failure: node '{}' is down", self.name);
            }
        }
        self.files
            .lock()
            .unwrap()
            .get(name)
            .map(|e| Arc::clone(&e.backend))
            .ok_or_else(|| anyhow::anyhow!("no file '{name}' on node '{}'", self.name))
    }

    pub fn delete_file(&self, name: &str) -> Result<()> {
        if let Some(inj) = &self.injector {
            inj.durable_event()?;
        }
        match self.files.lock().unwrap().remove(name) {
            Some(_) => {
                self.condemned.lock().unwrap().remove(name);
                Ok(())
            }
            None => bail!("no file '{name}' on node '{}'", self.name),
        }
    }

    pub fn file_names(&self) -> Vec<String> {
        self.list_ops.fetch_add(1, Relaxed);
        self.files.lock().unwrap().keys().cloned().collect()
    }

    /// Directory listings served over this node's lifetime (an O(fleet)
    /// scan fingerprint — see the HA recovery tests).
    pub fn list_ops(&self) -> u64 {
        self.list_ops.load(Relaxed)
    }

    /// Begin recording the byte extents writers dirty in `name` (the
    /// migration mirror's dirty-interval intercept). Returns the live
    /// log; drain it with [`WriteLog::drain`], stop with
    /// [`StorageNode::unwatch`].
    pub fn watch(&self, name: &str) -> Result<Arc<WriteLog>> {
        let files = self.files.lock().unwrap();
        let e = files
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no file '{name}' on node '{}'", self.name))?;
        e.log.begin();
        Ok(Arc::clone(&e.log))
    }

    /// Stop recording writes to `name` (no-op for unknown files).
    pub fn unwatch(&self, name: &str) {
        if let Some(e) = self.files.lock().unwrap().get(name) {
            e.log.end();
        }
    }

    /// Bytes physically stored across all files (capacity pressure).
    pub fn used_bytes(&self) -> u64 {
        self.files
            .lock()
            .unwrap()
            .values()
            .map(|e| e.backend.stored_bytes())
            .sum()
    }

    /// Mark `name` as condemned (GC deferred delete): its bytes stop
    /// counting against thin-provisioning pressure while the sweep is
    /// pending. No-op for files not on this node.
    pub fn mark_condemned(&self, name: &str) {
        let present = self.files.lock().unwrap().contains_key(name);
        if present {
            self.condemned.lock().unwrap().insert(name.to_string());
        }
    }

    /// Resurrect a condemned file (a chain re-referenced it before the
    /// sweep): its bytes count as pressure again.
    pub fn uncondemn(&self, name: &str) {
        self.condemned.lock().unwrap().remove(name);
    }

    /// Bytes held by condemned (pending-delete) files.
    pub fn condemned_bytes(&self) -> u64 {
        let files = self.files.lock().unwrap();
        self.condemned
            .lock()
            .unwrap()
            .iter()
            .filter_map(|n| files.get(n))
            .map(|e| e.backend.stored_bytes())
            .sum()
    }

    /// Capacity pressure: stored bytes minus condemned bytes — what the
    /// placement layer sees. Condemned files are as good as deleted for
    /// thin provisioning; the GC sweep makes it physical.
    pub fn pressure_bytes(&self) -> u64 {
        let files = self.files.lock().unwrap();
        let condemned = self.condemned.lock().unwrap();
        files
            .iter()
            .filter(|(n, _)| !condemned.contains(n.as_str()))
            .map(|(_, e)| e.backend.stored_bytes())
            .sum()
    }

    /// Reserve `bytes` of capacity for an in-flight migration copy.
    /// Fails when the reservation would not fit beside the current
    /// pressure — the recipient-side admission gate.
    pub fn reserve(&self, bytes: u64) -> Result<()> {
        let mut cur = self.reserved.load(Relaxed);
        loop {
            let committed = self
                .pressure_bytes()
                .saturating_add(cur)
                .saturating_add(bytes);
            if committed > self.capacity {
                bail!(
                    "node '{}' cannot reserve {bytes} bytes: {committed} committed \
                     of {} capacity",
                    self.name,
                    self.capacity
                );
            }
            match self
                .reserved
                .compare_exchange(cur, cur + bytes, Relaxed, Relaxed)
            {
                Ok(_) => return Ok(()),
                Err(now) => cur = now,
            }
        }
    }

    /// Give back a migration reservation (completion, cancel or failure).
    pub fn release(&self, bytes: u64) {
        let mut cur = self.reserved.load(Relaxed);
        loop {
            match self.reserved.compare_exchange(
                cur,
                cur.saturating_sub(bytes),
                Relaxed,
                Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Bytes currently reserved for in-flight migrations.
    pub fn reserved_bytes(&self) -> u64 {
        self.reserved.load(Relaxed)
    }

    /// Committed capacity: thin-provisioning pressure plus migration
    /// reservations — the ONE definition placement, admission, the
    /// rebalancer and reporting all share.
    pub fn committed_bytes(&self) -> u64 {
        self.pressure_bytes().saturating_add(self.reserved_bytes())
    }

    /// Record the result of a capacity scan: guest-addressable bytes
    /// mapped by the chains on this node. A cache for reporting, not an
    /// input to placement — physical pressure stays authoritative.
    pub fn set_logical_bytes(&self, bytes: u64) {
        self.logical.store(bytes, Relaxed);
    }

    /// Guest-addressable bytes as of the last capacity scan (0 before
    /// any scan). `logical / used` is the node's capacity multiplication
    /// from zero clusters, compression and dedup.
    pub fn logical_bytes(&self) -> u64 {
        self.logical.load(Relaxed)
    }

    /// Account a GC deletion of `bytes` (called by the sweep).
    pub fn note_reclaimed(&self, bytes: u64) {
        self.reclaimed.fetch_add(bytes, Relaxed);
        self.gc_deletes.fetch_add(1, Relaxed);
    }

    /// Bytes reclaimed by GC over this node's lifetime.
    pub fn reclaimed_bytes(&self) -> u64 {
        self.reclaimed.load(Relaxed)
    }

    /// Files deleted by GC over this node's lifetime.
    pub fn gc_deletes(&self) -> u64 {
        self.gc_deletes.load(Relaxed)
    }

    /// Would adding `bytes` exceed this node's capacity? Condemned files
    /// do not count (their deletion is already scheduled); migration
    /// reservations DO (their bytes are already committed).
    pub fn would_overflow(&self, bytes: u64) -> bool {
        self.committed_bytes().saturating_add(bytes) > self.capacity
    }

    /// Drop every piece of volatile bookkeeping (condemned marks,
    /// migration reservations, live write watches). Crash recovery calls
    /// this first: none of it survives a reboot — only file bytes do —
    /// and [`crate::coordinator::Coordinator::recover`] re-derives what
    /// still applies from the durable state.
    pub fn clear_volatile(&self) {
        self.condemned.lock().unwrap().clear();
        self.reserved.store(0, Relaxed);
        self.logical.store(0, Relaxed);
        for e in self.files.lock().unwrap().values() {
            e.log.end();
        }
    }

    pub fn clock(&self) -> &Arc<VirtClock> {
        &self.clock
    }

    /// The node device's I/O scheduler (merge windows, utilization).
    pub fn scheduler(&self) -> &Arc<IoScheduler> {
        &self.sched
    }

    pub fn cost(&self) -> CostModel {
        self.cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> Arc<StorageNode> {
        StorageNode::new("s1", VirtClock::new(), CostModel::default())
    }

    #[test]
    fn create_open_delete() {
        let n = node();
        let f = n.create_file("disk-0").unwrap();
        f.write_at(b"x", 0).unwrap();
        let g = n.open_file("disk-0").unwrap();
        assert_eq!(g.len(), 1);
        assert!(n.create_file("disk-0").is_err());
        n.delete_file("disk-0").unwrap();
        assert!(n.open_file("disk-0").is_err());
    }

    #[test]
    fn io_charges_node_clock() {
        let n = node();
        let f = n.create_file("d").unwrap();
        let t0 = n.clock().now();
        f.write_at(&[0u8; 512], 0).unwrap();
        assert!(n.clock().now() > t0);
    }

    #[test]
    fn condemned_files_drop_out_of_pressure_not_usage() {
        let clock = VirtClock::new();
        let n = StorageNode::with_capacity("tiny", clock, CostModel::default(), 128 << 10);
        let f = n.create_file("d").unwrap();
        f.write_at(&[1u8; 96 << 10], 0).unwrap();
        assert!(n.would_overflow(64 << 10));
        n.mark_condemned("d");
        // physically still there, but no longer thin-provisioning pressure
        assert_eq!(n.used_bytes(), 96 << 10);
        assert_eq!(n.condemned_bytes(), 96 << 10);
        assert_eq!(n.pressure_bytes(), 0);
        assert!(!n.would_overflow(64 << 10));
        // resurrect: pressure returns
        n.uncondemn("d");
        assert!(n.would_overflow(64 << 10));
        // deleting clears the mark and the usage together
        n.mark_condemned("d");
        n.delete_file("d").unwrap();
        assert_eq!(n.used_bytes(), 0);
        assert_eq!(n.condemned_bytes(), 0);
    }

    #[test]
    fn logical_bytes_cache_is_volatile() {
        let n = node();
        assert_eq!(n.logical_bytes(), 0, "no scan yet");
        n.set_logical_bytes(3 << 20);
        assert_eq!(n.logical_bytes(), 3 << 20);
        n.clear_volatile();
        assert_eq!(n.logical_bytes(), 0, "recovery rescans");
    }

    #[test]
    fn reclaim_counters_accumulate() {
        let n = node();
        n.note_reclaimed(100);
        n.note_reclaimed(28);
        assert_eq!(n.reclaimed_bytes(), 128);
        assert_eq!(n.gc_deletes(), 2);
    }

    #[test]
    fn capacity_accounting() {
        let clock = VirtClock::new();
        let n = StorageNode::with_capacity("tiny", clock, CostModel::default(), 128 << 10);
        let f = n.create_file("d").unwrap();
        f.write_at(&[1u8; 64 << 10], 0).unwrap();
        assert!(!n.would_overflow(0));
        assert!(n.would_overflow(128 << 10));
    }

    #[test]
    fn reservations_count_as_pressure_until_released() {
        let clock = VirtClock::new();
        let n = StorageNode::with_capacity("r", clock, CostModel::default(), 128 << 10);
        n.reserve(100 << 10).unwrap();
        assert_eq!(n.reserved_bytes(), 100 << 10);
        assert!(n.would_overflow(64 << 10), "reservation committed the space");
        assert!(
            n.reserve(64 << 10).is_err(),
            "a second reservation cannot overcommit"
        );
        n.release(100 << 10);
        assert_eq!(n.reserved_bytes(), 0);
        assert!(!n.would_overflow(64 << 10));
        // release is saturating: an over-release cannot underflow
        n.release(1 << 20);
        assert_eq!(n.reserved_bytes(), 0);
    }

    #[test]
    fn watch_records_file_writes_until_unwatch() {
        let n = node();
        let f = n.create_file("d").unwrap();
        f.write_at(&[1u8; 16], 0).unwrap(); // before the watch: invisible
        let log = n.watch("d").unwrap();
        f.write_at(&[2u8; 16], 64).unwrap();
        assert_eq!(log.drain(), vec![(64, 16)]);
        n.unwatch("d");
        f.write_at(&[3u8; 16], 128).unwrap();
        assert!(log.drain().is_empty());
        assert!(n.watch("nope").is_err());
    }

    #[test]
    fn clear_volatile_resets_bookkeeping_not_bytes() {
        let clock = VirtClock::new();
        let n = StorageNode::with_capacity("v", clock, CostModel::default(), 1 << 20);
        let f = n.create_file("d").unwrap();
        f.write_at(&[1u8; 4 << 10], 0).unwrap();
        n.mark_condemned("d");
        n.reserve(64 << 10).unwrap();
        let log = n.watch("d").unwrap();
        n.clear_volatile();
        assert_eq!(n.condemned_bytes(), 0);
        assert_eq!(n.reserved_bytes(), 0);
        assert!(!log.is_active());
        assert_eq!(n.used_bytes(), 64 << 10, "file bytes survive (one page)");
    }

    #[test]
    fn fault_injected_node_counts_namespace_events() {
        use crate::storage::fault::FaultInjector;
        let inj = FaultInjector::new();
        let clock = VirtClock::new();
        let n = StorageNode::with_fault_injection(
            "f",
            clock,
            CostModel::default(),
            u64::MAX,
            Arc::clone(&inj),
        );
        let f = n.create_file("d").unwrap(); // event 0
        f.write_at(&[1u8; 8], 0).unwrap(); // event 1
        assert_eq!(inj.events(), 2);
        inj.arm(0, None);
        assert!(n.create_file("e").is_err(), "create is cut");
        assert!(n.open_file("d").is_err(), "node is down");
        inj.revive();
        assert!(n.open_file("d").is_ok());
        let mut buf = [0u8; 8];
        n.open_file("d").unwrap().read_at(&mut buf, 0).unwrap();
        assert_eq!(buf, [1u8; 8], "durable bytes survive the cut");
    }
}
