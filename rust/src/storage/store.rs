//! `FileStore`: the namespace abstraction chains are opened through —
//! a single [`super::node::StorageNode`] or a multi-node
//! [`crate::coordinator::placement::NodeSet`] (chains can span storage
//! servers, §3 thin provisioning).

use super::backend::BackendRef;
use super::node::StorageNode;
use anyhow::Result;

/// A namespace of virtual-disk files.
pub trait FileStore: Send + Sync {
    fn create_file(&self, name: &str) -> Result<BackendRef>;
    fn open_file(&self, name: &str) -> Result<BackendRef>;
    fn delete_file(&self, name: &str) -> Result<()>;
}

impl<T: FileStore + ?Sized> FileStore for std::sync::Arc<T> {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        (**self).create_file(name)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        (**self).open_file(name)
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        (**self).delete_file(name)
    }
}

impl FileStore for StorageNode {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        // inherent methods take precedence in resolution, so these calls
        // are not recursive
        StorageNode::create_file(self, name)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        StorageNode::open_file(self, name)
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        StorageNode::delete_file(self, name)
    }
}
