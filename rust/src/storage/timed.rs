//! Latency-charging wrapper: the Eq. 1 cost model applied to a backend.
//!
//! Every `read_at`/`write_at`/`charge` advances the shared virtual clock by
//! `T_L + T_D + len/bandwidth` — one software/network hop plus one device
//! access plus the transfer. This is the NFS-served SSD of the paper's
//! testbed, reduced to its cost structure.

use super::backend::Backend;
use super::iosched::IoScheduler;
use crate::metrics::clock::{CostModel, VirtClock};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Backend decorator charging virtual time per operation.
pub struct Timed<B: Backend> {
    inner: B,
    clock: Arc<VirtClock>,
    cost: CostModel,
    ios: AtomicU64,
    bytes: AtomicU64,
    /// Node I/O scheduler plus this file's id on it. While a shard holds
    /// a merge window open on the scheduler, extents are billed through
    /// it (cross-VM merging); otherwise billing is the classic
    /// per-request path below, bit for bit.
    sched: Option<(Arc<IoScheduler>, u64)>,
}

impl<B: Backend> Timed<B> {
    pub fn new(inner: B, clock: Arc<VirtClock>, cost: CostModel) -> Self {
        Timed {
            inner,
            clock,
            cost,
            ios: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            sched: None,
        }
    }

    /// A timed file whose billing can be merged across VMs by the
    /// node's I/O scheduler (see [`super::iosched`]).
    pub fn with_scheduler(
        inner: B,
        clock: Arc<VirtClock>,
        cost: CostModel,
        sched: Arc<IoScheduler>,
    ) -> Self {
        let file_id = sched.register_file();
        let mut t = Timed::new(inner, clock, cost);
        t.sched = Some((sched, file_id));
        t
    }

    /// Total device I/O operations issued through this file.
    pub fn io_count(&self) -> u64 {
        self.ios.load(Ordering::Relaxed)
    }

    /// Total bytes transferred through this file.
    pub fn byte_count(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn pay(&self, len: u64) {
        self.clock.advance(self.cost.io_ns(len));
        self.ios.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len, Ordering::Relaxed);
    }

    /// Bill one extent: through the node scheduler when a merge window
    /// is open (an extent touching one already serviced in the window
    /// pays no seek and no re-transferred bytes), classic Eq. 1
    /// accounting otherwise.
    fn pay_at(&self, off: u64, len: u64) {
        if let Some((sched, file)) = &self.sched {
            if let Some(bill) = sched.try_bill(*file, off, len) {
                self.clock.advance(bill.ns);
                self.ios.fetch_add(bill.seeks, Ordering::Relaxed);
                self.bytes.fetch_add(bill.fresh, Ordering::Relaxed);
                return;
            }
        }
        self.pay(len);
    }
}

impl<B: Backend> Timed<B> {
    /// Bill a sorted iov list: each maximal physically contiguous run is
    /// ONE device I/O (`T_L + T_D` once) plus bandwidth for the run's
    /// total bytes — the Eq. 1 accounting of a merged device command.
    fn pay_runs(&self, spans: &[(u64, u64)]) {
        let mut i = 0;
        while i < spans.len() {
            let (start, len) = spans[i];
            let mut end = start + len;
            let mut j = i + 1;
            while j < spans.len() && spans[j].0 == end {
                end += spans[j].1;
                j += 1;
            }
            self.pay_at(start, end - start);
            i = j;
        }
    }
}

impl<B: Backend> Backend for Timed<B> {
    fn read_at(&self, buf: &mut [u8], off: u64) -> Result<()> {
        self.pay_at(off, buf.len() as u64);
        self.inner.read_at(buf, off)
    }

    fn write_at(&self, data: &[u8], off: u64) -> Result<()> {
        self.pay_at(off, data.len() as u64);
        self.inner.write_at(data, off)
    }

    fn read_vectored(&self, iovs: &mut [(u64, &mut [u8])]) -> Result<()> {
        let spans: Vec<(u64, u64)> =
            iovs.iter().map(|(off, buf)| (*off, buf.len() as u64)).collect();
        self.pay_runs(&spans);
        for iov in iovs.iter_mut() {
            self.inner.read_at(iov.1, iov.0)?;
        }
        Ok(())
    }

    fn write_vectored(&self, iovs: &[(u64, &[u8])]) -> Result<()> {
        let spans: Vec<(u64, u64)> =
            iovs.iter().map(|(off, data)| (*off, data.len() as u64)).collect();
        self.pay_runs(&spans);
        for (off, data) in iovs {
            self.inner.write_at(data, *off)?;
        }
        Ok(())
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn truncate_to(&self, len: u64) -> Result<()> {
        // metadata-only op: one layer traversal, no device transfer
        self.clock.advance(self.cost.t_layers);
        self.inner.truncate_to(len)
    }

    fn flush(&self) -> Result<()> {
        // a durability barrier is one round trip to the device (NFS
        // COMMIT): layer traversal + device access, no data transfer
        self.clock.advance(self.cost.io_ns(0));
        if let Some((sched, _)) = &self.sched {
            // count the barrier's busy time toward utilization
            sched.note_flush();
        }
        self.inner.flush()
    }

    fn shrink_to(&self, len: u64) -> Result<u64> {
        self.clock.advance(self.cost.t_layers);
        self.inner.shrink_to(len)
    }

    fn charge(&self, off: u64, len: u64) {
        self.pay_at(off, len);
    }

    fn stored_bytes(&self) -> u64 {
        self.inner.stored_bytes()
    }

    fn now_ns(&self) -> u64 {
        self.clock.now()
    }

    fn device_ios(&self) -> u64 {
        self.io_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::mem::MemBackend;

    #[test]
    fn charges_reads_and_writes() {
        let clock = VirtClock::new();
        let cost = CostModel::default();
        let b = Timed::new(MemBackend::new(), clock.clone(), cost);
        let t0 = clock.now();
        b.write_at(&[0u8; 4096], 0).unwrap();
        let after_write = clock.now();
        assert_eq!(after_write - t0, cost.io_ns(4096));
        let mut buf = [0u8; 64 << 10];
        b.read_at(&mut buf, 0).unwrap();
        assert_eq!(clock.now() - after_write, cost.io_ns(64 << 10));
        assert_eq!(b.io_count(), 2);
        assert_eq!(b.byte_count(), 4096 + (64 << 10));
    }

    #[test]
    fn vectored_contiguous_run_bills_one_seek() {
        let clock = VirtClock::new();
        let cost = CostModel::default();
        let b = Timed::new(MemBackend::new(), clock.clone(), cost);
        b.write_at(&[7u8; 128 << 10], 0).unwrap();
        let ios0 = b.io_count();
        let t0 = clock.now();
        let mut b1 = [0u8; 64 << 10];
        let mut b2 = [0u8; 64 << 10];
        let mut iovs: Vec<(u64, &mut [u8])> =
            vec![(0, b1.as_mut_slice()), (64 << 10, b2.as_mut_slice())];
        b.read_vectored(&mut iovs).unwrap();
        // one seek + bandwidth for 128 KiB, not two seeks
        assert_eq!(clock.now() - t0, cost.io_ns(128 << 10));
        assert_eq!(b.io_count() - ios0, 1);
        assert_eq!(b1, [7u8; 64 << 10]);
        assert_eq!(b2, [7u8; 64 << 10]);
    }

    #[test]
    fn vectored_write_run_bills_one_seek() {
        let clock = VirtClock::new();
        let cost = CostModel::default();
        let b = Timed::new(MemBackend::new(), clock.clone(), cost);
        let t0 = clock.now();
        let d1 = [1u8; 4096];
        let d2 = [2u8; 4096];
        b.write_vectored(&[(0, &d1[..]), (4096, &d2[..])]).unwrap();
        assert_eq!(clock.now() - t0, cost.io_ns(8192));
        assert_eq!(b.device_ios(), 1);
        let mut back = [0u8; 8192];
        b.read_at(&mut back, 0).unwrap();
        assert_eq!(&back[..4096], &d1);
        assert_eq!(&back[4096..], &d2);
    }

    #[test]
    fn vectored_discontiguous_pairs_bill_separately() {
        let clock = VirtClock::new();
        let cost = CostModel::default();
        let b = Timed::new(MemBackend::new(), clock.clone(), cost);
        let t0 = clock.now();
        let mut b1 = [0u8; 4096];
        let mut b2 = [0u8; 4096];
        let mut iovs: Vec<(u64, &mut [u8])> =
            vec![(0, b1.as_mut_slice()), (1 << 20, b2.as_mut_slice())];
        b.read_vectored(&mut iovs).unwrap();
        assert_eq!(clock.now() - t0, 2 * cost.io_ns(4096));
        assert_eq!(b.device_ios(), 2);
    }

    #[test]
    fn merge_window_bills_adjacent_requests_as_one_seek() {
        use crate::storage::iosched::{IoScheduler, MergeWindow};
        let clock = VirtClock::new();
        let cost = CostModel::default();
        let sched = IoScheduler::new(cost);
        let b = Timed::with_scheduler(
            MemBackend::new(),
            clock.clone(),
            cost,
            Arc::clone(&sched),
        );
        b.write_at(&[7u8; 128 << 10], 0).unwrap();
        // no window open: two separate requests bill two seeks (classic)
        let t0 = clock.now();
        let mut buf = [0u8; 64 << 10];
        b.read_at(&mut buf, 0).unwrap();
        b.read_at(&mut buf, 64 << 10).unwrap();
        assert_eq!(clock.now() - t0, 2 * cost.io_ns(64 << 10));
        // window open: the adjacent second request merges
        let w = MergeWindow::open(vec![Arc::clone(&sched)]);
        let t1 = clock.now();
        b.read_at(&mut buf, 0).unwrap();
        b.read_at(&mut buf, 64 << 10).unwrap();
        assert_eq!(
            clock.now() - t1,
            cost.io_ns(128 << 10),
            "one seek + bandwidth for both extents"
        );
        drop(w);
        assert_eq!(sched.snapshot().merged_seeks, 1);
    }

    #[test]
    fn charge_without_data() {
        let clock = VirtClock::new();
        let b = Timed::new(MemBackend::new(), clock.clone(), CostModel::default());
        b.charge(0, 64 << 10);
        assert!(clock.now() > 0);
        assert_eq!(b.len(), 0); // nothing stored
    }
}
