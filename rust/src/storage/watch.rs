//! Byte-interval write watching: the storage-layer analogue of the
//! [`crate::blockjob::JobFence`] write intercept.
//!
//! A live migration ([`crate::migrate::MirrorJob`]) copies a file while
//! the guest keeps writing to it. The [`JobFence`] tracks guest writes at
//! *virtual-cluster* granularity, which is enough for jobs that rewrite
//! L2 entries — but a mirror must replicate the file byte-for-byte,
//! including metadata the drivers mutate outside the fence's view (L2
//! tables, refcount blocks, header slots, allocator growth). So every
//! file a [`crate::storage::node::StorageNode`] serves is wrapped in a
//! [`Watched`] backend holding a [`WriteLog`]: while a watch is active,
//! every mutation records its byte extent; the mirror drains the log
//! between copy passes and re-copies exactly the intervals that changed.
//! When no watch is active the wrapper costs one relaxed atomic load per
//! write.
//!
//! [`JobFence`]: crate::blockjob::JobFence

use super::backend::{Backend, BackendRef};
use crate::util::lock_unpoisoned;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Extent marker meaning "the whole file may have changed" (recorded for
/// operations, like `shrink_to`, whose effect is not a simple overwrite).
pub const DIRTY_ALL: u64 = u64::MAX;

/// Dirty byte extents of one file, recorded while a watch is active.
#[derive(Debug, Default)]
pub struct WriteLog {
    active: AtomicBool,
    dirty: Mutex<Vec<(u64, u64)>>,
}

impl WriteLog {
    /// Begin recording (clears anything a previous watch left behind).
    pub fn begin(&self) {
        lock_unpoisoned(&self.dirty).clear();
        self.active.store(true, Ordering::Release);
    }

    /// Stop recording and drop the pending extents.
    pub fn end(&self) {
        self.active.store(false, Ordering::Release);
        lock_unpoisoned(&self.dirty).clear();
    }

    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Acquire)
    }

    /// Record a mutated `[off, off+len)` extent. `len == DIRTY_ALL`
    /// invalidates the whole file.
    pub fn note(&self, off: u64, len: u64) {
        if len == 0 || !self.is_active() {
            return;
        }
        lock_unpoisoned(&self.dirty).push((off, len));
    }

    /// Take the recorded extents, coalesced (sorted, overlapping and
    /// adjacent ranges merged). Recording continues — extents noted
    /// after the drain land in the next one.
    pub fn drain(&self) -> Vec<(u64, u64)> {
        let mut v = std::mem::take(&mut *lock_unpoisoned(&self.dirty));
        if v.is_empty() {
            return v;
        }
        // a whole-file marker swallows everything else
        if v.iter().any(|&(_, len)| len == DIRTY_ALL) {
            return vec![(0, DIRTY_ALL)];
        }
        v.sort_unstable();
        let mut out: Vec<(u64, u64)> = Vec::with_capacity(v.len());
        for (off, len) in v {
            match out.last_mut() {
                Some((o, l)) if off <= *o + *l => {
                    let end = (off + len).max(*o + *l);
                    *l = end - *o;
                }
                _ => out.push((off, len)),
            }
        }
        out
    }

    /// Extents currently pending (diagnostics).
    pub fn pending(&self) -> usize {
        lock_unpoisoned(&self.dirty).len()
    }
}

/// Backend decorator feeding a [`WriteLog`]; reads and accounting pass
/// straight through. Extents are noted BEFORE the inner write, so a
/// failed or torn write is conservatively marked dirty.
pub struct Watched {
    inner: BackendRef,
    log: Arc<WriteLog>,
}

impl Watched {
    pub fn new(inner: BackendRef, log: Arc<WriteLog>) -> Watched {
        Watched { inner, log }
    }

    pub fn log(&self) -> &Arc<WriteLog> {
        &self.log
    }
}

impl Backend for Watched {
    fn read_at(&self, buf: &mut [u8], off: u64) -> Result<()> {
        self.inner.read_at(buf, off)
    }

    fn write_at(&self, data: &[u8], off: u64) -> Result<()> {
        self.log.note(off, data.len() as u64);
        self.inner.write_at(data, off)
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn truncate_to(&self, len: u64) -> Result<()> {
        // growth writes no bytes; the mirror tracks length separately
        self.inner.truncate_to(len)
    }

    fn flush(&self) -> Result<()> {
        self.inner.flush()
    }

    fn shrink_to(&self, len: u64) -> Result<u64> {
        // discarding a tail is not an overwrite: invalidate everything
        self.log.note(0, DIRTY_ALL);
        self.inner.shrink_to(len)
    }

    fn read_vectored(&self, iovs: &mut [(u64, &mut [u8])]) -> Result<()> {
        self.inner.read_vectored(iovs)
    }

    fn write_vectored(&self, iovs: &[(u64, &[u8])]) -> Result<()> {
        for (off, data) in iovs {
            self.log.note(*off, data.len() as u64);
        }
        self.inner.write_vectored(iovs)
    }

    fn charge(&self, off: u64, len: u64) {
        self.inner.charge(off, len)
    }

    fn stored_bytes(&self) -> u64 {
        self.inner.stored_bytes()
    }

    fn device_ios(&self) -> u64 {
        self.inner.device_ios()
    }

    fn now_ns(&self) -> u64 {
        self.inner.now_ns()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::mem::MemBackend;

    fn watched() -> (Arc<WriteLog>, Watched) {
        let log = Arc::new(WriteLog::default());
        let w = Watched::new(Arc::new(MemBackend::new()), Arc::clone(&log));
        (log, w)
    }

    #[test]
    fn records_only_while_active() {
        let (log, w) = watched();
        w.write_at(&[1u8; 8], 0).unwrap();
        assert!(log.drain().is_empty(), "inactive log records nothing");
        log.begin();
        w.write_at(&[2u8; 8], 100).unwrap();
        assert_eq!(log.drain(), vec![(100, 8)]);
        log.end();
        w.write_at(&[3u8; 8], 200).unwrap();
        assert!(log.drain().is_empty());
    }

    #[test]
    fn drain_coalesces_overlapping_and_adjacent() {
        let (log, w) = watched();
        log.begin();
        w.write_at(&[1u8; 10], 50).unwrap(); // 50..60
        w.write_at(&[1u8; 10], 0).unwrap(); // 0..10
        w.write_at(&[1u8; 10], 10).unwrap(); // adjacent: 0..20
        w.write_at(&[1u8; 20], 55).unwrap(); // overlap: 50..75
        assert_eq!(log.drain(), vec![(0, 20), (50, 25)]);
        assert!(log.drain().is_empty(), "drain empties the log");
    }

    #[test]
    fn shrink_marks_whole_file() {
        let (log, w) = watched();
        log.begin();
        w.write_at(&[1u8; 100], 0).unwrap();
        w.shrink_to(10).unwrap();
        assert_eq!(log.drain(), vec![(0, DIRTY_ALL)]);
    }

    #[test]
    fn vectored_writes_and_passthrough() {
        let (log, w) = watched();
        log.begin();
        w.write_vectored(&[(0, &[1u8; 4][..]), (100, &[2u8; 4][..])])
            .unwrap();
        let mut buf = [0u8; 4];
        w.read_at(&mut buf, 100).unwrap();
        assert_eq!(buf, [2u8; 4]);
        assert_eq!(log.drain(), vec![(0, 4), (100, 4)]);
        assert_eq!(w.len(), 104);
    }
}
