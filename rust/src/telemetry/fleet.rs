//! The standard collector set over a [`Coordinator`]: every subsystem's
//! existing stats surfaced as one registry.
//!
//! [`register_fleet`] wires eight collectors into the coordinator's
//! registry, one per subsystem:
//!
//! | collector   | family prefix                  | source                    |
//! |-------------|--------------------------------|---------------------------|
//! | guest       | `sqemu_guest_`                 | per-VM [`VmStats`]        |
//! | coordinator | `sqemu_shard_`                 | shard executor stats      |
//! | storage     | `sqemu_node_`, `sqemu_iosched_`| nodes + I/O schedulers    |
//! | blockjob    | `sqemu_jobs_`, `sqemu_job_`    | the sharded job ledgers   |
//! | migrate     | `sqemu_migrate_`               | mirror-job convergence    |
//! | gc          | `sqemu_gc_`                    | [`GcRegistry`] totals     |
//! | dedup       | `sqemu_dedup_`                 | [`DedupIndex`] stats+ops  |
//! | control     | `sqemu_control_`               | [`StateStore`] status     |
//! | trace       | `sqemu_trace_`                 | the shared [`TraceRing`]  |
//!
//! Ownership: collectors for coordinator-level views hold a
//! `Weak<Coordinator>` (the coordinator owns the registry, so a strong
//! reference here would be a leak cycle); collectors over free-standing
//! subsystems (nodes, GC, dedup, trace) hold their `Arc` directly.
//!
//! Cardinality contract (DESIGN.md §17): per-VM families export scalar
//! counters and p50/p99 gauges only — the full latency histogram is
//! fleet-aggregated, so label count grows O(vms) in lines, never
//! O(vms × buckets). Job families are emitted for every [`JobKind`]
//! even at zero, so the metric-name inventory is load-independent.
//!
//! Collection cost: scrape-time reads of shared atomics plus brief leaf
//! locks (per-VM latency histograms, subsystem tables). Guest counters
//! are read without a shard stats barrier — a scrape may lag the
//! serving pass that is currently batching deltas by one flush, which
//! is invisible to a monotone exporter. Nothing here runs on, or locks
//! against, the serving cone.

use super::registry::{Collector, Registry, SampleSet};
use super::trace::TraceRing;
use crate::blockjob::{JobKind, JobState};
use crate::coordinator::placement::NodeSet;
use crate::coordinator::server::Coordinator;
use crate::dedup::DedupIndex;
use crate::gc::GcRegistry;
use crate::metrics::histogram::Histogram;
use std::sync::{Arc, Weak};

/// Register the standard fleet collectors into `coord`'s registry.
/// Called once by [`Coordinator::new`] right after the coordinator is
/// in its `Arc`.
pub fn register_fleet(coord: &Arc<Coordinator>) {
    let reg: Arc<Registry> = Arc::clone(coord.telemetry());
    let weak = Arc::downgrade(coord);
    reg.register(Arc::new(GuestCollector { coord: weak.clone() }));
    reg.register(Arc::new(ShardCollector { coord: weak.clone() }));
    reg.register(Arc::new(NodeCollector { nodes: Arc::clone(&coord.nodes) }));
    reg.register(Arc::new(JobCollector { coord: weak.clone() }));
    reg.register(Arc::new(GcCollector {
        gc: Arc::clone(coord.gc_registry()),
    }));
    reg.register(Arc::new(DedupCollector {
        dedup: Arc::clone(coord.dedup_index()),
    }));
    reg.register(Arc::new(ControlCollector { coord: weak }));
    reg.register(Arc::new(TraceCollector {
        ring: Arc::clone(coord.trace_ring()),
    }));
}

// ------------------------------------------------------------- guest

/// Per-VM service counters plus the fleet-aggregated latency histogram.
struct GuestCollector {
    coord: Weak<Coordinator>,
}

impl Collector for GuestCollector {
    fn collect(&self, out: &mut SampleSet) {
        let Some(coord) = self.coord.upgrade() else { return };
        let mut fleet_latency = Histogram::new();
        for (vm, stats) in coord.vm_stat_handles() {
            let s = stats.snapshot();
            let l = &[("vm", vm.as_str())];
            out.counter("sqemu_guest_reads_total", "Guest read requests served.", l, s.reads);
            out.counter("sqemu_guest_writes_total", "Guest write requests served.", l, s.writes);
            out.counter("sqemu_guest_read_bytes_total", "Guest bytes read.", l, s.bytes_read);
            out.counter("sqemu_guest_written_bytes_total", "Guest bytes written.", l, s.bytes_written);
            out.counter("sqemu_guest_batched_ops_total", "Guest ops served through the vectored path.", l, s.batched_ops);
            out.counter("sqemu_guest_merged_ios_total", "Device reads that merged >= 2 cluster segments.", l, s.merged_ios);
            out.counter("sqemu_guest_coalesced_bytes_total", "Bytes moved by merged device reads.", l, s.coalesced_bytes);
            out.counter("sqemu_guest_backpressure_total", "Requests blocked on a full submission ring.", l, s.backpressure);
            out.counter("sqemu_guest_snapshots_total", "Live snapshots taken.", l, s.snapshots);
            out.counter("sqemu_guest_streams_total", "Offline stream-merges run.", l, s.streams);
            out.counter("sqemu_guest_worker_panics_total", "VM workers lost to a panic.", l, s.worker_panics);
            out.gauge("sqemu_guest_req_p50_ns", "Median guest request latency (virtual ns).", l, s.req_p50_ns as f64);
            out.gauge("sqemu_guest_req_p99_ns", "p99 guest request latency (virtual ns).", l, s.req_p99_ns as f64);
            fleet_latency.merge(&stats.latency_histogram());
        }
        // one histogram for the whole fleet: per-VM bucket series would
        // be O(vms x buckets) lines (the cardinality rule)
        out.histogram(
            "sqemu_guest_req_latency_ns",
            "Guest request latency, enqueue to reply, all VMs (virtual ns).",
            &[],
            &fleet_latency,
        );
    }
}

// ------------------------------------------------------- coordinator

/// Shard executor stats: the `sqemu serve` shard table as families.
struct ShardCollector {
    coord: Weak<Coordinator>,
}

impl Collector for ShardCollector {
    fn collect(&self, out: &mut SampleSet) {
        let Some(coord) = self.coord.upgrade() else { return };
        for s in coord.shard_stats() {
            let shard = s.shard.to_string();
            let l = &[("shard", shard.as_str())];
            out.gauge("sqemu_shard_vms", "VMs owned by this shard executor.", l, s.vms as f64);
            out.gauge("sqemu_shard_queue_depth", "Live submission-ring occupancy across this shard's VMs.", l, s.queued as f64);
            out.counter("sqemu_shard_served_total", "Guest submissions served by this shard.", l, s.served);
            out.counter("sqemu_shard_passes_total", "Serving passes run by this shard.", l, s.passes);
            out.counter("sqemu_shard_wakeups_total", "Park wakeups taken by this shard.", l, s.wakeups);
        }
    }
}

// ----------------------------------------------------------- storage

/// Per-node capacity levels and I/O-scheduler device counters.
struct NodeCollector {
    nodes: Arc<NodeSet>,
}

impl Collector for NodeCollector {
    fn collect(&self, out: &mut SampleSet) {
        for n in self.nodes.nodes() {
            let l = &[("node", n.name.as_str())];
            out.gauge("sqemu_node_used_bytes", "Stored bytes across this node's files.", l, n.used_bytes() as f64);
            out.gauge("sqemu_node_pressure_bytes", "Stored bytes minus condemned (what GC cannot yet reclaim).", l, n.pressure_bytes() as f64);
            out.gauge("sqemu_node_reserved_bytes", "Capacity reserved by admitted migrations.", l, n.reserved_bytes() as f64);
            out.gauge("sqemu_node_condemned_bytes", "Bytes awaiting deferred deletion.", l, n.condemned_bytes() as f64);
            out.gauge("sqemu_node_logical_bytes", "Guest-addressable mapped bytes attributed to this node.", l, n.logical_bytes() as f64);
            out.counter("sqemu_node_reclaimed_bytes_total", "Bytes physically reclaimed by GC sweeps.", l, n.reclaimed_bytes());
            out.counter("sqemu_node_gc_deletes_total", "Files GC physically deleted.", l, n.gc_deletes());
            out.counter("sqemu_node_list_ops_total", "Directory listings served (the paper's list-op cost).", l, n.list_ops());
            let io = n.scheduler().snapshot();
            out.counter("sqemu_iosched_busy_ns_total", "Device-busy virtual ns billed by the cost model.", l, io.busy_ns);
            out.counter("sqemu_iosched_fresh_bytes_total", "Bytes transferred at device bandwidth.", l, io.fresh_bytes);
            out.counter("sqemu_iosched_seeks_total", "Seeks billed.", l, io.seeks);
            out.counter("sqemu_iosched_merged_seeks_total", "Seeks elided by cross-VM extent merging.", l, io.merged_seeks);
            out.counter("sqemu_iosched_window_opens_total", "Merge windows opened.", l, io.window_opens);
            out.gauge("sqemu_node_device_utilization", "Fraction of device-busy time spent transferring bytes.", l, n.scheduler().utilization());
        }
    }
}

// ---------------------------------------------------- blockjob + migrate

/// The sharded job ledgers, tallied per kind — plus the migrate view
/// (mirror counts and convergence lag) derived from the same ledger.
struct JobCollector {
    coord: Weak<Coordinator>,
}

impl Collector for JobCollector {
    fn collect(&self, out: &mut SampleSet) {
        let Some(coord) = self.coord.upgrade() else { return };
        const KINDS: [JobKind; 5] = [
            JobKind::Stream,
            JobKind::Stamp,
            JobKind::Gc,
            JobKind::Mirror,
            JobKind::Scan,
        ];
        #[derive(Default)]
        struct Tally {
            started: u64,
            running: u64,
            completed: u64,
            failed: u64,
            cancelled: u64,
            increments: u64,
            copied_bytes: u64,
            processed: u64,
        }
        let mut per_kind: [Tally; 5] = Default::default();
        let mut lag = 0u64;
        let mut mirrors_done = 0u64;
        for (_, st) in coord.list_jobs() {
            let t = &mut per_kind[KINDS.iter().position(|k| *k == st.kind).unwrap_or(0)];
            t.started += 1;
            match st.state {
                JobState::Running | JobState::Paused => t.running += 1,
                JobState::Completed => t.completed += 1,
                JobState::Failed => t.failed += 1,
                JobState::Cancelled => t.cancelled += 1,
            }
            t.increments += st.increments;
            t.copied_bytes += st.bytes_copied;
            t.processed += st.processed;
            if st.kind == JobKind::Mirror {
                if st.state.is_terminal() {
                    mirrors_done += 1;
                } else {
                    // clusters the mirror still has to drain before it
                    // can converge and switch over
                    lag += st.total.saturating_sub(st.processed);
                }
            }
        }
        // every kind is always emitted (zeros included) so the exported
        // name/label inventory does not depend on what jobs have run
        for (kind, t) in KINDS.iter().zip(&per_kind) {
            let l = &[("kind", kind.name())];
            out.counter("sqemu_jobs_started_total", "Block jobs ever started.", l, t.started);
            out.counter("sqemu_jobs_completed_total", "Block jobs finished successfully.", l, t.completed);
            out.counter("sqemu_jobs_failed_total", "Block jobs ended in failure.", l, t.failed);
            out.counter("sqemu_jobs_cancelled_total", "Block jobs cancelled.", l, t.cancelled);
            out.gauge("sqemu_jobs_running", "Block jobs currently live (running or paused).", l, t.running as f64);
            out.counter("sqemu_job_increments_total", "Bounded job increments executed.", l, t.increments);
            out.counter("sqemu_job_copied_bytes_total", "Bytes copied by job increments.", l, t.copied_bytes);
            out.counter("sqemu_job_processed_clusters_total", "Virtual clusters examined by job increments.", l, t.processed);
        }
        out.counter(
            "sqemu_migrate_mirrors_completed_total",
            "Mirror migrations that reached switchover (terminal).",
            &[],
            mirrors_done,
        );
        out.gauge(
            "sqemu_migrate_convergence_lag_clusters",
            "Clusters live mirrors still have to drain before switchover.",
            &[],
            lag as f64,
        );
    }
}

// ---------------------------------------------------------------- gc

struct GcCollector {
    gc: Arc<GcRegistry>,
}

impl Collector for GcCollector {
    fn collect(&self, out: &mut SampleSet) {
        out.counter("sqemu_gc_runs_total", "GC sweeps run.", &[], self.gc.gc_runs());
        out.counter("sqemu_gc_reclaimed_bytes_total", "Bytes reclaimed by GC sweeps.", &[], self.gc.reclaimed_total());
        out.counter("sqemu_gc_files_deleted_total", "Files GC deleted.", &[], self.gc.files_deleted());
        out.gauge("sqemu_gc_condemned_files", "Files in the deferred-delete set.", &[], self.gc.condemned_count() as f64);
        out.gauge("sqemu_gc_condemned_bytes", "Bytes in the deferred-delete set.", &[], self.gc.condemned_bytes() as f64);
    }
}

// ------------------------------------------------------------- dedup

struct DedupCollector {
    dedup: Arc<DedupIndex>,
}

impl Collector for DedupCollector {
    fn collect(&self, out: &mut SampleSet) {
        let s = self.dedup.fleet_stats();
        let ops = self.dedup.op_counts();
        out.gauge("sqemu_dedup_extents", "Shareable extents currently indexed.", &[], s.extents as f64);
        out.gauge("sqemu_dedup_refs", "Total sharers across indexed extents.", &[], s.refs as f64);
        out.counter("sqemu_dedup_saved_bytes_total", "Guest bytes served by sharing instead of allocation.", &[], s.saved_bytes);
        out.counter("sqemu_dedup_shares_total", "Writes served by referencing an existing extent (hits).", &[], ops.shares);
        out.counter("sqemu_dedup_cow_releases_total", "Extent references dropped by overwrite/free (CoW breaks).", &[], ops.releases);
        out.counter("sqemu_dedup_retires_total", "Extents withdrawn from sharing by in-place overwrite.", &[], ops.retires);
    }
}

// ----------------------------------------------------------- control

/// StateStore status, when a control plane is attached. A fleet without
/// one exports no `sqemu_control_` families — attachment is itself the
/// signal.
struct ControlCollector {
    coord: Weak<Coordinator>,
}

impl Collector for ControlCollector {
    fn collect(&self, out: &mut SampleSet) {
        let Some(coord) = self.coord.upgrade() else { return };
        let Ok(st) = coord.control_status() else { return };
        out.gauge("sqemu_control_epoch", "Leadership epoch of the attached control plane.", &[], st.epoch as f64);
        out.gauge("sqemu_control_generation", "Log compaction generation.", &[], st.generation as f64);
        out.gauge("sqemu_control_log_bytes", "Bytes in the active control log.", &[], st.log_bytes as f64);
        out.gauge("sqemu_control_records", "Records in the active control log.", &[], st.records as f64);
        out.gauge("sqemu_control_leases", "VM ownership leases currently held.", &[], st.leases as f64);
        out.gauge("sqemu_control_wedged", "1 when the store refused further appends after torn I/O.", &[], if st.wedged { 1.0 } else { 0.0 });
        out.counter("sqemu_control_appends_total", "Records appended to the control log.", &[], st.appends);
        out.counter("sqemu_control_compactions_total", "Log compactions completed.", &[], st.compactions);
        out.counter("sqemu_control_lease_renewals_total", "Lease renewals granted.", &[], st.lease_renewals);
    }
}

// ------------------------------------------------------------- trace

struct TraceCollector {
    ring: Arc<TraceRing>,
}

impl Collector for TraceCollector {
    fn collect(&self, out: &mut SampleSet) {
        out.counter("sqemu_trace_events_total", "Span events ever recorded by sampled VMs.", &[], self.ring.total());
        out.counter("sqemu_trace_dropped_total", "Span events lost to ring eviction or slot overflow.", &[], self.ring.dropped());
        out.gauge("sqemu_trace_buffered", "Span events currently buffered in the ring.", &[], self.ring.len() as f64);
    }
}

#[cfg(test)]
mod tests {
    use crate::coordinator::server::Coordinator;

    #[test]
    fn fresh_fleet_exports_core_subsystems() {
        let coord = Coordinator::with_fresh_nodes(2).unwrap();
        let names = coord.telemetry().metric_names();
        for prefix in [
            "sqemu_shard_",
            "sqemu_node_",
            "sqemu_iosched_",
            "sqemu_jobs_",
            "sqemu_job_",
            "sqemu_migrate_",
            "sqemu_gc_",
            "sqemu_dedup_",
            "sqemu_trace_",
        ] {
            assert!(
                names.iter().any(|n| n.starts_with(prefix)),
                "no family with prefix {prefix}: {names:?}"
            );
        }
        // guest families appear with VMs; the fleet aggregate is always on
        assert!(names.contains(&"sqemu_guest_req_latency_ns".to_string()));
        // no control plane attached: no control families
        assert!(!names.iter().any(|n| n.starts_with("sqemu_control_")));
        coord.shutdown();
    }

    #[test]
    fn render_is_nonempty_and_well_typed() {
        let coord = Coordinator::with_fresh_nodes(1).unwrap();
        let text = coord.telemetry().render();
        assert!(text.contains("# TYPE sqemu_node_used_bytes gauge"));
        assert!(text.contains("# TYPE sqemu_gc_runs_total counter"));
        assert!(text.contains("sqemu_jobs_started_total{kind=\"mirror\"} 0 "));
        coord.shutdown();
    }
}
