//! Fleet telemetry plane: one observable surface over every subsystem.
//!
//! The paper's argument is quantitative (chain-length effects on
//! latency, memory and device utilization), and every PR since has
//! grown its own ad-hoc stats struct — `VmStats`, `NodeStats`, shard
//! tables, GC/dedup/control totals — each printable only by its own CLI
//! verb or bench. This module unifies them:
//!
//! * [`registry::Registry`] — a pull-based metrics registry. Subsystems
//!   register [`registry::Collector`]s that snapshot their *existing*
//!   shared counters (the `Arc`'d atomics the reaper pattern already
//!   maintains) at scrape time; nothing new runs on the serve path.
//!   [`registry::Registry::render`] emits Prometheus text format with
//!   virtual-clock timestamps (`sqemu metrics`, the `sqemu serve`
//!   scrape hook, the `observability` CI job).
//! * [`trace`] — ring-buffered span events for request→shard→node hops
//!   on trace-sampled VMs. The per-VM [`trace::TraceBuf`] is plain
//!   executor-owned state (no locks on the serve path); the shard's
//!   stats reaper flushes it into the shared [`trace::TraceRing`] once
//!   per serving pass, exactly like [`crate::coordinator::stats::StatsDelta`].
//! * [`fleet`] — the standard collector set over a
//!   [`crate::coordinator::Coordinator`]: coordinator shards, storage
//!   nodes + I/O schedulers, block jobs, GC, dedup, migration, the HA
//!   control plane, and per-VM guest service stats.
//!
//! Collection contract (DESIGN.md §17): scrape-time reads of shared
//! atomics and brief control-plane locks only — the shard serving cone
//! (`sqemu-lint` `serving-lock`) stays lock-free, and per-VM label
//! cardinality is bounded (per-VM families export scalars; full
//! latency histograms are fleet-aggregated; tracing is sampled).

pub mod fleet;
pub mod registry;
pub mod trace;

pub use registry::{Collector, Family, Kind, Registry, Sample, SampleSet, SampleValue};
pub use trace::{SpanEvent, TraceBuf, TraceRing};
