//! Typed metric families and the Prometheus-text-format exporter.
//!
//! Pull model: a [`Collector`] owns (or weakly references) a
//! subsystem's existing stats and, at scrape time, pushes point-in-time
//! [`Sample`]s into a [`SampleSet`]. The registry itself holds no
//! metric state — every value is read fresh from the same shared
//! counters the subsystem already maintains, so registering a
//! collector adds zero work to any hot path.
//!
//! Rendering follows the Prometheus text exposition format: `# HELP` /
//! `# TYPE` per family, escaped label values, cumulative `_bucket`
//! lines (from [`Histogram::buckets`]) plus `_sum`/`_count` for
//! histograms, and a virtual-clock timestamp (milliseconds) on every
//! sample line.

use crate::metrics::clock::VirtClock;
use crate::metrics::histogram::Histogram;
use crate::util::lock_unpoisoned;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

/// Metric family type, as rendered in `# TYPE`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// One sample's value.
#[derive(Clone, Debug)]
pub enum SampleValue {
    /// Monotone cumulative count (name should end in `_total`).
    Counter(u64),
    /// Point-in-time level.
    Gauge(f64),
    /// Full distribution; rendered as `_bucket`/`_sum`/`_count`.
    Histo(Histogram),
}

/// One labelled sample of a family.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Label pairs in insertion order (callers keep them sorted enough;
    /// uniqueness per family is the caller's cardinality contract).
    pub labels: Vec<(String, String)>,
    pub value: SampleValue,
}

/// A named family: `# HELP`/`# TYPE` plus its samples.
#[derive(Clone, Debug)]
pub struct Family {
    pub name: String,
    pub help: String,
    pub kind: Kind,
    pub samples: Vec<Sample>,
}

/// Scrape-time accumulator handed to every collector. Families are
/// keyed by name; two collectors contributing to the same family must
/// agree on its kind (debug-asserted).
#[derive(Default)]
pub struct SampleSet {
    families: BTreeMap<String, Family>,
}

impl SampleSet {
    fn family(&mut self, name: &str, help: &str, kind: Kind) -> &mut Family {
        debug_assert!(valid_name(name), "invalid metric name '{name}'");
        let f = self.families.entry(name.to_string()).or_insert_with(|| Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            samples: Vec::new(),
        });
        debug_assert_eq!(f.kind, kind, "family '{name}' registered twice with different kinds");
        f
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: u64) {
        self.family(name, help, Kind::Counter)
            .samples
            .push(Sample { labels: own(labels), value: SampleValue::Counter(v) });
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        self.family(name, help, Kind::Gauge)
            .samples
            .push(Sample { labels: own(labels), value: SampleValue::Gauge(v) });
    }

    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        h: &Histogram,
    ) {
        self.family(name, help, Kind::Histogram)
            .samples
            .push(Sample { labels: own(labels), value: SampleValue::Histo(h.clone()) });
    }
}

fn own(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

fn valid_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// A subsystem's scrape hook. Implementations read existing shared
/// state (atomics, brief control-plane locks) — they must not hold
/// anything across the call that a serving pass could block on.
pub trait Collector: Send + Sync {
    fn collect(&self, out: &mut SampleSet);
}

/// The fleet-wide registry. One per coordinator; subsystems register at
/// construction, exporters call [`Registry::render`].
pub struct Registry {
    clock: Arc<VirtClock>,
    /// Registered collectors. Cloned out before collecting so the lock
    /// is never held while a collector takes subsystem locks (it stays
    /// a leaf in the lock hierarchy).
    collectors: Mutex<Vec<Arc<dyn Collector>>>,
}

impl Registry {
    pub fn new(clock: Arc<VirtClock>) -> Arc<Registry> {
        Arc::new(Registry { clock, collectors: Mutex::new(Vec::new()) })
    }

    pub fn register(&self, c: Arc<dyn Collector>) {
        lock_unpoisoned(&self.collectors).push(c);
    }

    /// Snapshot every collector into sorted families.
    pub fn gather(&self) -> Vec<Family> {
        let collectors: Vec<Arc<dyn Collector>> =
            lock_unpoisoned(&self.collectors).clone();
        let mut set = SampleSet::default();
        for c in &collectors {
            c.collect(&mut set);
        }
        set.families.into_values().collect()
    }

    /// Sorted family names — the metric-name inventory
    /// (`telemetry/metrics.txt`, the CI `observability` diff).
    pub fn metric_names(&self) -> Vec<String> {
        self.gather().into_iter().map(|f| f.name).collect()
    }

    /// Render a scrape in Prometheus text exposition format. Timestamps
    /// are the virtual clock at gather time, in milliseconds.
    pub fn render(&self) -> String {
        let ts = self.clock.now() / 1_000_000;
        let mut out = String::new();
        for f in self.gather() {
            let _ = writeln!(out, "# HELP {} {}", f.name, escape_help(&f.help));
            let _ = writeln!(out, "# TYPE {} {}", f.name, f.kind.name());
            for s in &f.samples {
                render_sample(&mut out, &f.name, s, ts);
            }
        }
        out
    }
}

fn render_sample(out: &mut String, name: &str, s: &Sample, ts: u64) {
    match &s.value {
        SampleValue::Counter(v) => {
            let _ = writeln!(out, "{name}{} {v} {ts}", label_block(&s.labels, None));
        }
        SampleValue::Gauge(v) => {
            let _ = writeln!(
                out,
                "{name}{} {} {ts}",
                label_block(&s.labels, None),
                fmt_f64(*v)
            );
        }
        SampleValue::Histo(h) => {
            for (le, cum) in h.buckets() {
                let _ = writeln!(
                    out,
                    "{name}_bucket{} {cum} {ts}",
                    label_block(&s.labels, Some(&le.to_string()))
                );
            }
            let _ = writeln!(
                out,
                "{name}_bucket{} {} {ts}",
                label_block(&s.labels, Some("+Inf")),
                h.count()
            );
            let _ = writeln!(
                out,
                "{name}_sum{} {} {ts}",
                label_block(&s.labels, None),
                h.sum()
            );
            let _ = writeln!(
                out,
                "{name}_count{} {} {ts}",
                label_block(&s.labels, None),
                h.count()
            );
        }
    }
}

/// Gauges may be fractional; render integers without the trailing `.0`
/// noise and non-finite values per the text-format spec.
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn label_block(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in labels {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "{k}=\"{}\"", escape_label(v));
    }
    if let Some(le) = le {
        if !first {
            out.push(',');
        }
        let _ = write!(out, "le=\"{le}\"");
    }
    out.push('}');
    out
}

/// Label-value escaping per the text format: backslash, double-quote
/// and newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// HELP escaping: backslash and newline (quotes are legal there).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    struct One;
    impl Collector for One {
        fn collect(&self, out: &mut SampleSet) {
            out.counter("t_reads_total", "reads", &[("vm", "a")], 7);
            out.gauge("t_depth", "queue depth", &[], 2.5);
            let mut h = Histogram::new();
            h.record(100);
            h.record(200_000);
            out.histogram("t_lat_ns", "latency", &[("vm", "a")], &h);
        }
    }

    fn reg() -> Arc<Registry> {
        let r = Registry::new(VirtClock::new());
        r.register(Arc::new(One));
        r
    }

    #[test]
    fn renders_help_type_and_samples() {
        let text = reg().render();
        assert!(text.contains("# HELP t_reads_total reads"));
        assert!(text.contains("# TYPE t_reads_total counter"));
        assert!(text.contains("t_reads_total{vm=\"a\"} 7 "));
        assert!(text.contains("# TYPE t_depth gauge"));
        assert!(text.contains("t_depth 2.5 "));
    }

    #[test]
    fn histogram_renders_cumulative_buckets_sum_count() {
        let text = reg().render();
        assert!(text.contains("# TYPE t_lat_ns histogram"));
        assert!(text.contains("t_lat_ns_bucket{vm=\"a\",le=\"+Inf\"} 2 "));
        assert!(text.contains("t_lat_ns_sum{vm=\"a\"} 200100 "));
        assert!(text.contains("t_lat_ns_count{vm=\"a\"} 2 "));
        // cumulative: counts along le never decrease
        let mut last = 0u64;
        for l in text.lines().filter(|l| l.starts_with("t_lat_ns_bucket")) {
            let v: u64 = l.split_whitespace().nth(1).unwrap().parse().unwrap();
            assert!(v >= last, "non-cumulative bucket line: {l}");
            last = v;
        }
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        let block = label_block(&[("vm".into(), "x\"y".into())], None);
        assert_eq!(block, "{vm=\"x\\\"y\"}");
    }

    #[test]
    fn metric_names_sorted_unique() {
        let names = reg().metric_names();
        assert_eq!(names, vec!["t_depth", "t_lat_ns", "t_reads_total"]);
    }

    #[test]
    fn name_validation() {
        assert!(valid_name("sqemu_node_used_bytes"));
        assert!(valid_name("_x:y"));
        assert!(!valid_name("9abc"));
        assert!(!valid_name("a-b"));
        assert!(!valid_name(""));
    }
}
