//! Ring-buffered span events for trace-sampled VMs.
//!
//! A sampled VM's shard slot carries a [`TraceBuf`]: plain
//! executor-owned state the serving pass appends to with no locks or
//! atomics (the [`crate::coordinator::stats::StatsDelta`] discipline).
//! The shard's per-pass stats reaper flushes pending events into the
//! fleet-shared [`TraceRing`], a bounded mutex-guarded ring that drops
//! the oldest events under pressure and is dumpable as JSON
//! (`sqemu serve --trace FILE`, `sqemu metrics --trace FILE`).
//!
//! Cardinality rule: per-VM tracing is *sampled*
//! ([`crate::coordinator::CoordinatorConfig::trace_sample`] picks every
//! Nth launched VM); the unsampled majority carries `None` and pays one
//! branch per request.

use crate::util::lock_unpoisoned;
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

/// Pending events one slot may hold between reaper flushes; beyond
/// this the serving pass drops (counted) rather than grow unbounded.
const PENDING_CAP: usize = 4096;

/// One traced request: the request→shard→node hop timestamps of a
/// single ring submission, in virtual ns.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Owning VM (shared; one allocation per sampled VM, not per event).
    pub vm: Arc<str>,
    /// Ring tag of the submission.
    pub tag: u64,
    /// Request kind: "read", "write", "batch" or "flush".
    pub kind: &'static str,
    /// Payload bytes (ops count for "batch", 0 for "flush").
    pub len: u64,
    /// Guest enqueue into the submission ring.
    pub enq_ns: u64,
    /// Shard executor dequeued it (start of service).
    pub serve_ns: u64,
    /// Storage-node completion posted back to the guest.
    pub done_ns: u64,
}

struct RingInner {
    events: VecDeque<SpanEvent>,
    /// Events ever recorded (kept + evicted + slot-dropped).
    total: u64,
    /// Events lost to ring eviction or a full pending buffer.
    dropped: u64,
}

/// Fleet-shared bounded event ring. The mutex is a leaf lock touched
/// only by per-pass reaper flushes and dump/scrape readers — never by
/// a serving pass.
pub struct TraceRing {
    cap: usize,
    inner: Mutex<RingInner>,
}

impl TraceRing {
    pub fn new(cap: usize) -> Arc<TraceRing> {
        Arc::new(TraceRing {
            cap: cap.max(1),
            inner: Mutex::new(RingInner {
                events: VecDeque::new(),
                total: 0,
                dropped: 0,
            }),
        })
    }

    /// Reaper-side bulk append (plus `slot_dropped` events a full
    /// pending buffer discarded before they got here).
    pub fn extend(&self, events: impl IntoIterator<Item = SpanEvent>, slot_dropped: u64) {
        let mut inner = lock_unpoisoned(&self.inner);
        inner.total += slot_dropped;
        inner.dropped += slot_dropped;
        for e in events {
            inner.total += 1;
            if inner.events.len() >= self.cap {
                inner.events.pop_front();
                inner.dropped += 1;
            }
            inner.events.push_back(e);
        }
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events ever recorded (including dropped).
    pub fn total(&self) -> u64 {
        lock_unpoisoned(&self.inner).total
    }

    /// Events lost to eviction or slot-buffer overflow.
    pub fn dropped(&self) -> u64 {
        lock_unpoisoned(&self.inner).dropped
    }

    /// Copy out the buffered events, oldest first.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        lock_unpoisoned(&self.inner).events.iter().cloned().collect()
    }

    /// Dump the buffered spans as a JSON document.
    pub fn to_json(&self) -> String {
        let (events, total, dropped) = {
            let inner = lock_unpoisoned(&self.inner);
            (
                inner.events.iter().cloned().collect::<Vec<_>>(),
                inner.total,
                inner.dropped,
            )
        };
        let mut out = String::new();
        let _ = writeln!(out, "{{");
        let _ = writeln!(out, "  \"schema\": \"sqemu-trace/1\",");
        let _ = writeln!(out, "  \"total\": {total},");
        let _ = writeln!(out, "  \"dropped\": {dropped},");
        let _ = writeln!(out, "  \"spans\": [");
        for (i, e) in events.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"vm\": \"{}\", \"tag\": {}, \"kind\": \"{}\", \
                 \"len\": {}, \"enq_ns\": {}, \"serve_ns\": {}, \
                 \"done_ns\": {}}}",
                json_escape(&e.vm),
                e.tag,
                e.kind,
                e.len,
                e.enq_ns,
                e.serve_ns,
                e.done_ns,
            );
            let _ = writeln!(out, "{}", if i + 1 < events.len() { "," } else { "" });
        }
        let _ = writeln!(out, "  ]");
        let _ = writeln!(out, "}}");
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Per-slot event accumulator for one trace-sampled VM. Owned by the
/// VM's shard executor: `record` is called from the serving pass (plain
/// vec push, bounded), `flush` from the per-pass stats reaper.
pub struct TraceBuf {
    vm: Arc<str>,
    ring: Arc<TraceRing>,
    pending: Vec<SpanEvent>,
    dropped: u64,
}

impl TraceBuf {
    pub fn new(vm: &str, ring: Arc<TraceRing>) -> TraceBuf {
        TraceBuf { vm: Arc::from(vm), ring, pending: Vec::new(), dropped: 0 }
    }

    /// Record one served request's hop timestamps (serving pass; no
    /// locks — drops beyond [`PENDING_CAP`] until the next flush).
    pub fn record(
        &mut self,
        tag: u64,
        kind: &'static str,
        len: u64,
        enq_ns: u64,
        serve_ns: u64,
        done_ns: u64,
    ) {
        if self.pending.len() >= PENDING_CAP {
            self.dropped += 1;
            return;
        }
        self.pending.push(SpanEvent {
            vm: Arc::clone(&self.vm),
            tag,
            kind,
            len,
            enq_ns,
            serve_ns,
            done_ns,
        });
    }

    /// Drain pending events into the shared ring (reaper path).
    pub fn flush(&mut self) {
        if self.pending.is_empty() && self.dropped == 0 {
            return;
        }
        let dropped = std::mem::take(&mut self.dropped);
        self.ring.extend(self.pending.drain(..), dropped);
    }
}

impl Drop for TraceBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(ring: &Arc<TraceRing>) -> TraceBuf {
        TraceBuf::new("vm-0", Arc::clone(ring))
    }

    #[test]
    fn record_flush_snapshot_roundtrip() {
        let ring = TraceRing::new(16);
        let mut b = buf(&ring);
        b.record(1, "read", 4096, 10, 20, 30);
        b.record(2, "write", 512, 11, 21, 31);
        assert_eq!(ring.len(), 0, "nothing shared before the reaper flush");
        b.flush();
        let spans = ring.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(&*spans[0].vm, "vm-0");
        assert_eq!(spans[1].kind, "write");
        assert!(spans[0].enq_ns <= spans[0].serve_ns);
        assert_eq!(ring.total(), 2);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let ring = TraceRing::new(4);
        let mut b = buf(&ring);
        for i in 0..10 {
            b.record(i, "read", 4096, i, i, i);
        }
        b.flush();
        assert_eq!(ring.len(), 4, "bounded");
        assert_eq!(ring.total(), 10);
        assert_eq!(ring.dropped(), 6);
        // oldest evicted: the survivors are the newest four
        assert_eq!(ring.snapshot()[0].tag, 6);
    }

    #[test]
    fn json_dump_is_well_formed_enough() {
        let ring = TraceRing::new(8);
        let mut b = TraceBuf::new("vm\"x", Arc::clone(&ring));
        b.record(7, "flush", 0, 1, 2, 3);
        b.flush();
        let j = ring.to_json();
        assert!(j.contains("\"schema\": \"sqemu-trace/1\""));
        assert!(j.contains("\\\"x"), "vm name escaped: {j}");
        assert!(j.contains("\"tag\": 7"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn drop_flushes_pending() {
        let ring = TraceRing::new(8);
        {
            let mut b = buf(&ring);
            b.record(1, "read", 1, 1, 1, 1);
        }
        assert_eq!(ring.len(), 1, "TraceBuf::drop flushed");
    }
}
