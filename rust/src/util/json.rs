//! Minimal JSON reader — just enough for `artifacts/manifest.json`.
//!
//! No `serde` in the offline crate set, and the manifest schema is under
//! our control (python/compile/aot.py), so a small recursive-descent parser
//! is the honest substrate. Supports the full JSON value grammar minus
//! exotic escapes (\uXXXX surrogate pairs are passed through lossily).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.into() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("eof escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                _ => {
                    // multibyte utf-8: copy the full scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{s}'") })
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{
            "constants": {"batch": 256, "unallocated": -1},
            "artifacts": {
                "merge_l2": {
                    "file": "merge_l2.hlo.txt",
                    "inputs": [{"shape": [8192], "dtype": "int32"}],
                    "outputs": [{"shape": [8192], "dtype": "int32"}]
                }
            }
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(
            j.get("constants").unwrap().get("batch").unwrap().as_u64(),
            Some(256)
        );
        assert_eq!(
            j.get("constants").unwrap().get("unallocated").unwrap().as_i64(),
            Some(-1)
        );
        let m = j.get("artifacts").unwrap().get("merge_l2").unwrap();
        assert_eq!(m.get("file").unwrap().as_str(), Some("merge_l2.hlo.txt"));
        let shape = m.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_u64(), Some(8192));
    }

    #[test]
    fn scalars_and_strings() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse(r#""a\n\"b\" A""#).unwrap(),
            Json::Str("a\n\"b\" A".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_u64(), Some(3));
    }
}
