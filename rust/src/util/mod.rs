//! Substrate utilities built from scratch for the offline environment:
//! a minimal JSON reader (AOT manifest), a deterministic PRNG, a tiny
//! property-testing driver, size/format helpers and summary statistics.

pub mod json;
pub mod notify;
pub(crate) mod sync_shim;
pub mod prop;
pub mod retry;
pub mod rng;
pub mod stats;

pub use notify::Notify;

/// Format a byte count using binary units (the units the paper plots in).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn human_ns(ns: u64) -> String {
    match ns {
        0..=999 => format!("{ns} ns"),
        1_000..=999_999 => format!("{:.1} µs", ns as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.2} ms", ns as f64 / 1e6),
        _ => format!("{:.3} s", ns as f64 / 1e9),
    }
}

/// Parse sizes like `64K`, `2M`, `50G`, `4096` (binary multipliers).
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1u64 << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1u64 << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1u64 << 30),
        't' | 'T' => (&s[..s.len() - 1], 1u64 << 40),
        _ => (s, 1),
    };
    let num = num.trim();
    if let Ok(v) = num.parse::<u64>() {
        return Some(v * mult);
    }
    num.parse::<f64>().ok().map(|v| (v * mult as f64) as u64)
}

/// Integer division rounding up.
pub const fn div_ceil(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

/// Lock a mutex, recovering from poison: a panic on one VM worker must
/// not cascade into every other client of the coordinator's shared maps
/// and stats (the dead VM surfaces as an error on its own channel only).
/// The guarded data here is counters/registries whose invariants hold
/// between individual writes, so the poison flag carries no information
/// worth dying for.
pub fn lock_unpoisoned<T: ?Sized>(
    m: &std::sync::Mutex<T>,
) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod poison_tests {
    use super::lock_unpoisoned;
    use std::sync::Mutex;

    #[test]
    fn recovers_data_after_a_panicking_holder() {
        let m = Mutex::new(7u32);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("holder dies");
        }));
        assert!(m.is_poisoned());
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 9;
        assert_eq!(*lock_unpoisoned(&m), 9);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(50 << 30), "50.0 GiB");
    }

    #[test]
    fn human_ns_units() {
        assert_eq!(human_ns(80_000), "80.0 µs");
        assert_eq!(human_ns(100), "100 ns");
        assert_eq!(human_ns(1_500_000), "1.50 ms");
    }

    #[test]
    fn parse_size_suffixes() {
        assert_eq!(parse_size("64K"), Some(64 << 10));
        assert_eq!(parse_size("50G"), Some(50 << 30));
        assert_eq!(parse_size("4096"), Some(4096));
        assert_eq!(parse_size("2.5M"), Some((2.5 * 1024.0 * 1024.0) as u64));
        assert_eq!(parse_size("bogus"), None);
    }

    #[test]
    fn div_ceil_edges() {
        assert_eq!(div_ceil(0, 8), 0);
        assert_eq!(div_ceil(1, 8), 1);
        assert_eq!(div_ceil(8, 8), 1);
        assert_eq!(div_ceil(9, 8), 2);
    }
}
