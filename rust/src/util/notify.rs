//! Parked wakeup: a latching condition-variable doorbell.
//!
//! The sharded data plane parks an executor when none of its VMs have
//! submission-queue entries or runnable jobs; submitters, control
//! messages and job resume/cancel ring the doorbell. The flag latches,
//! so a notification delivered between the executor's "nothing to do"
//! check and its park is never lost — `wait` returns immediately.

use crate::util::sync_shim::{Condvar, Mutex};
use std::sync::PoisonError;
use std::time::Duration;

/// A latching wakeup signal (Mutex<bool> + Condvar).
///
/// `notify` sets the flag and wakes all waiters; `wait`/`wait_timeout`
/// block until the flag is set, then consume it. Poisoning is recovered
/// like every other coordinator lock: the flag's invariant holds between
/// individual writes. Built on [`crate::util::sync_shim`] so the loom
/// CI job can model-check the latch for lost wakeups.
#[derive(Debug)]
pub struct Notify {
    flag: Mutex<bool>,
    cv: Condvar,
}

impl Default for Notify {
    fn default() -> Self {
        Notify::new()
    }
}

impl Notify {
    pub fn new() -> Self {
        Notify { flag: Mutex::new(false), cv: Condvar::new() }
    }

    /// Ring the doorbell: latch the flag and wake every parked waiter.
    pub fn notify(&self) {
        let mut g = self
            .flag
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        *g = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Park until notified (consumes the latched flag).
    pub fn wait(&self) {
        let mut g = self
            .flag
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        while !*g {
            g = self
                .cv
                .wait(g)
                .unwrap_or_else(PoisonError::into_inner);
        }
        *g = false;
    }

    /// Park until notified or `timeout` elapses. Returns true if a
    /// notification was consumed, false on timeout.
    #[cfg(not(loom))]
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self
            .flag
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        while !*g {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _res) = self
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
        }
        *g = false;
        true
    }

    /// loom has no `Condvar::wait_timeout`; under the model checker a
    /// timed park degrades to an untimed one (models never rely on
    /// timeouts for progress — the backstops exist for lost-wakeup
    /// defense in depth, and the loom suite proves wakeups aren't lost).
    #[cfg(loom)]
    pub fn wait_timeout(&self, _timeout: Duration) -> bool {
        self.wait();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn notify_before_wait_is_not_lost() {
        let n = Notify::new();
        n.notify();
        // the latched flag makes this return immediately
        n.wait();
    }

    #[test]
    fn wait_timeout_reports_outcome() {
        let n = Notify::new();
        assert!(!n.wait_timeout(Duration::from_millis(5)), "no signal");
        n.notify();
        assert!(n.wait_timeout(Duration::from_millis(5)), "latched signal");
    }

    #[test]
    fn cross_thread_wakeup() {
        let n = Arc::new(Notify::new());
        let n2 = Arc::clone(&n);
        let h = std::thread::spawn(move || {
            n2.wait();
            7u32
        });
        std::thread::sleep(Duration::from_millis(10));
        n.notify();
        assert_eq!(h.join().unwrap(), 7);
    }
}

#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use loom::thread;
    use std::sync::Arc;

    /// The doorbell's core guarantee: a notify racing an executor's
    /// park is never lost. If the latch had a window (flag checked,
    /// notify fires, then the wait parks), loom would report the
    /// deadlocked interleaving here.
    #[test]
    fn loom_notify_wakeup_never_lost() {
        loom::model(|| {
            let n = Arc::new(Notify::new());
            let n2 = Arc::clone(&n);
            let t = thread::spawn(move || n2.wait());
            n.notify();
            t.join().unwrap();
        });
    }

    /// Latching: a notification delivered before anyone waits is
    /// consumed by the next waiter instead of evaporating.
    #[test]
    fn loom_notify_latches_before_wait() {
        loom::model(|| {
            let n = Notify::new();
            n.notify();
            n.wait(); // must return immediately off the latched flag
        });
    }

    /// Concurrent redundant rings collapse into the latch without
    /// losing the wakeup: the waiter returns no matter how the two
    /// notifies interleave with its park.
    #[test]
    fn loom_notify_redundant_notifies_collapse() {
        loom::model(|| {
            let n = Arc::new(Notify::new());
            let n1 = Arc::clone(&n);
            let n2 = Arc::clone(&n);
            let t1 = thread::spawn(move || n1.notify());
            let t2 = thread::spawn(move || n2.notify());
            n.wait();
            t1.join().unwrap();
            t2.join().unwrap();
        });
    }
}
