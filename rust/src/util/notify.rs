//! Parked wakeup: a latching condition-variable doorbell.
//!
//! The sharded data plane parks an executor when none of its VMs have
//! submission-queue entries or runnable jobs; submitters, control
//! messages and job resume/cancel ring the doorbell. The flag latches,
//! so a notification delivered between the executor's "nothing to do"
//! check and its park is never lost — `wait` returns immediately.

use std::sync::{Condvar, Mutex, PoisonError};
use std::time::Duration;

/// A latching wakeup signal (Mutex<bool> + Condvar).
///
/// `notify` sets the flag and wakes all waiters; `wait`/`wait_timeout`
/// block until the flag is set, then consume it. Poisoning is recovered
/// like every other coordinator lock: the flag's invariant holds between
/// individual writes.
#[derive(Debug, Default)]
pub struct Notify {
    flag: Mutex<bool>,
    cv: Condvar,
}

impl Notify {
    pub fn new() -> Self {
        Notify::default()
    }

    /// Ring the doorbell: latch the flag and wake every parked waiter.
    pub fn notify(&self) {
        let mut g = self
            .flag
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        *g = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Park until notified (consumes the latched flag).
    pub fn wait(&self) {
        let mut g = self
            .flag
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        while !*g {
            g = self
                .cv
                .wait(g)
                .unwrap_or_else(PoisonError::into_inner);
        }
        *g = false;
    }

    /// Park until notified or `timeout` elapses. Returns true if a
    /// notification was consumed, false on timeout.
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self
            .flag
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        while !*g {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _res) = self
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
        }
        *g = false;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn notify_before_wait_is_not_lost() {
        let n = Notify::new();
        n.notify();
        // the latched flag makes this return immediately
        n.wait();
    }

    #[test]
    fn wait_timeout_reports_outcome() {
        let n = Notify::new();
        assert!(!n.wait_timeout(Duration::from_millis(5)), "no signal");
        n.notify();
        assert!(n.wait_timeout(Duration::from_millis(5)), "latched signal");
    }

    #[test]
    fn cross_thread_wakeup() {
        let n = Arc::new(Notify::new());
        let n2 = Arc::clone(&n);
        let h = std::thread::spawn(move || {
            n2.wait();
            7u32
        });
        std::thread::sleep(Duration::from_millis(10));
        n.notify();
        assert_eq!(h.join().unwrap(), 7);
    }
}
