//! Tiny property-testing driver (no `proptest` in the offline crate set).
//!
//! `forall(seed, cases, |rng| ...)` runs a closure over `cases` independent
//! deterministic RNG streams; on failure it reports the failing case seed so
//! the case can be replayed exactly (`replay(case_seed, |rng| ...)`).
//! No shrinking — cases are kept small instead.

use super::rng::Rng;

/// Run `f` for `cases` random cases. Panics with the case seed on failure.
pub fn forall(seed: u64, cases: u32, f: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let case_seed = seed ^ ((case as u64) << 32) ^ 0xA5A5_5A5A;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(case_seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases}; replay with \
                 util::prop::replay({case_seed:#x}, ...)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay(case_seed: u64, f: impl Fn(&mut Rng)) {
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(1, 50, |rng| {
            let a = rng.below(100);
            let b = rng.below(100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failures() {
        let r = std::panic::catch_unwind(|| {
            forall(2, 50, |rng| assert!(rng.below(10) < 9));
        });
        assert!(r.is_err());
    }
}
