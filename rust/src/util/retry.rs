//! Jittered exponential backoff with a hard deadline.
//!
//! The HA control plane retries in two places: a standby coordinator
//! tailing the StateStore log while the leader may be mid-compaction,
//! and lease renewal/acquisition racing a not-yet-expired holder. Both
//! want the same shape — retry with exponentially growing, jittered
//! sleeps until a deadline — and both run against the *virtual* clock
//! in tests, so the policy takes `now` and `sleep` as closures instead
//! of touching wall time directly.

use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// Backoff schedule: `base * 2^attempt`, capped at `max_delay`, with
/// each sleep jittered uniformly in `[delay/2, delay]` (decorrelated
/// enough to break thundering herds, bounded enough to test).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// First sleep, in ns (before jitter).
    pub base_ns: u64,
    /// Upper bound on any single sleep, in ns (before jitter).
    pub max_delay_ns: u64,
    /// Give up once `now` passes `start + deadline_ns`.
    pub deadline_ns: u64,
}

impl RetryPolicy {
    pub fn new(base_ns: u64, max_delay_ns: u64, deadline_ns: u64) -> Self {
        RetryPolicy { base_ns, max_delay_ns, deadline_ns }
    }

    /// The un-jittered delay for `attempt` (0-based): `base << attempt`,
    /// saturating (a schedule past 2^63 ns is "forever" here), capped.
    fn raw_delay(&self, attempt: u32) -> u64 {
        let shift = attempt.min(63);
        let exp = if self.base_ns != 0 && shift >= self.base_ns.leading_zeros() {
            u64::MAX
        } else {
            self.base_ns << shift
        };
        exp.min(self.max_delay_ns)
    }

    /// The jittered sleep for `attempt`: uniform in `[raw/2, raw]`.
    pub fn jittered_delay(&self, attempt: u32, rng: &mut Rng) -> u64 {
        let raw = self.raw_delay(attempt);
        if raw <= 1 {
            return raw;
        }
        let half = raw / 2;
        half + rng.below(raw - half + 1)
    }

    /// Run `op` until it succeeds or the deadline passes. `now` supplies
    /// the current time in ns; `sleep` advances it (virtual clock in
    /// tests, `thread::sleep` in a live process). The last error is
    /// wrapped with the attempt count when the deadline expires.
    pub fn run<T>(
        &self,
        seed: u64,
        now: impl Fn() -> u64,
        mut sleep: impl FnMut(u64),
        mut op: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let start = now();
        let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
        let mut attempt: u32 = 0;
        loop {
            let err = match op() {
                Ok(v) => return Ok(v),
                Err(e) => e,
            };
            let elapsed = now().saturating_sub(start);
            if elapsed >= self.deadline_ns {
                return Err(anyhow!(
                    "retry deadline expired after {} attempts: {err}",
                    attempt + 1
                ));
            }
            let delay = self
                .jittered_delay(attempt, &mut rng)
                .min(self.deadline_ns - elapsed);
            sleep(delay.max(1));
            attempt = attempt.saturating_add(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    fn virt_clock() -> (std::rc::Rc<Cell<u64>>, impl Fn() -> u64, impl FnMut(u64)) {
        let t = std::rc::Rc::new(Cell::new(0u64));
        let t1 = std::rc::Rc::clone(&t);
        let t2 = std::rc::Rc::clone(&t);
        (t, move || t1.get(), move |ns| t2.set(t2.get() + ns))
    }

    #[test]
    fn succeeds_after_transient_failures() {
        let p = RetryPolicy::new(1_000, 1_000_000, 10_000_000);
        let (_, now, sleep) = virt_clock();
        let mut fails = 3;
        let r = p.run(7, now, sleep, || {
            if fails > 0 {
                fails -= 1;
                Err(anyhow!("transient"))
            } else {
                Ok(42)
            }
        });
        assert_eq!(r.unwrap(), 42);
    }

    #[test]
    fn deadline_expiry_reports_attempts_and_last_error() {
        let p = RetryPolicy::new(1_000, 1_000_000, 50_000);
        let (t, now, sleep) = virt_clock();
        let r: Result<()> = p.run(7, now, sleep, || Err(anyhow!("always down")));
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("deadline expired"), "{msg}");
        assert!(msg.contains("always down"), "{msg}");
        assert!(
            t.get() <= 50_000 + 1_000_000,
            "sleeps are clamped near the deadline, got {}",
            t.get()
        );
    }

    #[test]
    fn jitter_stays_within_half_to_full_delay() {
        let p = RetryPolicy::new(1 << 20, 1 << 30, u64::MAX);
        let mut rng = Rng::new(99);
        for attempt in 0..12 {
            let raw = (1u64 << 20) << attempt;
            let raw = raw.min(1 << 30);
            for _ in 0..64 {
                let d = p.jittered_delay(attempt, &mut rng);
                assert!(d >= raw / 2 && d <= raw, "attempt {attempt}: {d} vs raw {raw}");
            }
        }
    }

    #[test]
    fn exponential_growth_caps_at_max_delay() {
        let p = RetryPolicy::new(1_000, 8_000, u64::MAX);
        assert_eq!(p.raw_delay(0), 1_000);
        assert_eq!(p.raw_delay(1), 2_000);
        assert_eq!(p.raw_delay(3), 8_000);
        assert_eq!(p.raw_delay(10), 8_000, "capped");
        assert_eq!(p.raw_delay(u32::MAX), 8_000, "huge attempt index saturates");
    }
}
