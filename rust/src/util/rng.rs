//! Deterministic PRNG (xoshiro256**) — no `rand` crate in the offline set.
//!
//! Every stochastic component in the simulator (workloads, chain
//! generation, the §3 trace model, property tests) takes an explicit seed
//! so all experiments are reproducible run-to-run.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; n must be > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed value with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Pareto-distributed value (heavy tail) with scale `xm`, shape `alpha`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        xm / u.powf(1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from cumulative weights (`cum` strictly increasing,
    /// last element = total weight).
    pub fn weighted(&mut self, cum: &[u64]) -> usize {
        let total = *cum.last().expect("non-empty weights");
        let x = self.below(total);
        cum.partition_point(|&c| c <= x)
    }

    /// Deterministic pseudo-random bytes (synthetic cluster payloads).
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(11);
        let mean = 80.0;
        let sum: f64 = (0..20_000).map(|_| r.exp(mean)).sum();
        let got = sum / 20_000.0;
        assert!((got - mean).abs() / mean < 0.05, "got={got}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let cum = [10u64, 10, 110]; // weights 10, 0, 100
        let mut counts = [0u64; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&cum)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_deterministic() {
        let mut a = [0u8; 37];
        let mut b = [0u8; 37];
        Rng::new(1).fill_bytes(&mut a);
        Rng::new(1).fill_bytes(&mut b);
        assert_eq!(a, b);
        assert_ne!(a, [0u8; 37]);
    }
}
