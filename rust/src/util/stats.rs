//! Summary statistics for the bench harness and reports.

/// Online summary of a sample set (used by the bench harness).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by nearest-rank on a sorted copy (q in [0, 100]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let rank = ((q / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Empirical CDF over u64 values — the §3 characterization figures plot
/// CDFs, so this is the common output type of `characterize::`.
#[derive(Clone, Debug)]
pub struct Cdf {
    /// sorted values
    pub values: Vec<u64>,
}

impl Cdf {
    pub fn new(mut values: Vec<u64>) -> Self {
        values.sort_unstable();
        Cdf { values }
    }

    /// Fraction of samples <= v.
    pub fn at(&self, v: u64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.partition_point(|&x| x <= v) as f64 / self.values.len() as f64
    }

    /// Value at the given quantile (q in [0,1]).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.values.is_empty() {
            return 0;
        }
        let rank = (q * (self.values.len() as f64 - 1.0)).round() as usize;
        self.values[rank.min(self.values.len() - 1)]
    }

    /// Sampled (value, fraction) points for printing a figure-like series.
    pub fn series(&self, points: usize) -> Vec<(u64, f64)> {
        if self.values.is_empty() {
            return vec![];
        }
        (0..=points)
            .map(|i| {
                let q = i as f64 / points as f64;
                let v = self.quantile(q);
                (v, self.at(v))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for v in 0..100 {
            s.add(v as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 99.0);
        assert!((s.percentile(90.0) - 89.0).abs() <= 1.0);
    }

    #[test]
    fn cdf_fractions() {
        let c = Cdf::new(vec![1, 2, 2, 3, 10]);
        assert_eq!(c.at(0), 0.0);
        assert_eq!(c.at(2), 0.6);
        assert_eq!(c.at(10), 1.0);
        assert_eq!(c.quantile(0.0), 1);
        assert_eq!(c.quantile(1.0), 10);
    }
}
