//! Concurrency primitives, switchable to [loom](https://docs.rs/loom)
//! instrumented versions with `RUSTFLAGS="--cfg loom"`.
//!
//! The lock-free ring ([`crate::coordinator::ring::Ring`]) and the
//! doorbell ([`crate::util::Notify`]) build against these aliases so the
//! loom CI job can model-check every interleaving of their atomics,
//! while the default build compiles straight to the `std` types with
//! zero overhead. The `loom` crate is injected by the CI job only
//! (`[target.'cfg(loom)'.dev-dependencies]`); the checked-in manifest
//! carries no extra dependency and a plain `cargo build` never sees it.

#[cfg(loom)]
pub(crate) use loom::sync::atomic::{
    AtomicBool, AtomicU64, AtomicUsize, Ordering,
};
#[cfg(not(loom))]
pub(crate) use std::sync::atomic::{
    AtomicBool, AtomicU64, AtomicUsize, Ordering,
};

#[cfg(loom)]
pub(crate) use loom::sync::{Condvar, Mutex};
#[cfg(not(loom))]
pub(crate) use std::sync::{Condvar, Mutex};

#[cfg(loom)]
pub(crate) use loom::thread::yield_now;
#[cfg(not(loom))]
pub(crate) use std::thread::yield_now;

#[cfg(loom)]
pub(crate) use loom::cell::UnsafeCell;

/// `std` stand-in for `loom::cell::UnsafeCell`: same `with`/`with_mut`
/// closure API (which loom uses to track reads and writes for race
/// detection), compiled down to plain pointer access.
#[cfg(not(loom))]
#[derive(Debug)]
pub(crate) struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

#[cfg(not(loom))]
impl<T> UnsafeCell<T> {
    pub(crate) fn new(v: T) -> UnsafeCell<T> {
        UnsafeCell(std::cell::UnsafeCell::new(v))
    }

    #[allow(dead_code)]
    pub(crate) fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        f(self.0.get())
    }

    pub(crate) fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }
}
