//! State and helpers shared by both drivers.

use crate::blockjob::JobFence;
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CacheCounters;
use crate::metrics::histogram::Histogram;
use crate::metrics::memory::{MemCategory, MemoryAccountant, Registration};
use crate::qcow::entry::L2Entry;
use crate::qcow::Chain;
use anyhow::Result;
use std::sync::Arc;

/// Per-snapshot driver state a hypervisor keeps besides the caches (BDS,
/// AIO rings, refcount caches, throttling state, ...) — §4.3 found these
/// contribute a smaller but chain-length-proportional footprint in BOTH
/// designs ("sQEMU's memory overhead still slightly increases with the
/// chain size ... due to other per-snapshot data structures", §6.2).
/// Calibrated to Fig 12's sqemu residue: ~0.2 MiB per snapshot.
pub const DRIVER_STATE_BYTES: u64 = 200 << 10;

/// Reusable fetch-path scratch: the raw slice bytes and decoded entries
/// of the most recent cache-miss fetch (§Perf: one scratch pair reused
/// across all misses instead of two allocations per miss).
#[derive(Default)]
pub struct SliceScratch {
    pub raw: Vec<u8>,
    pub entries: Vec<u64>,
}

/// Everything both drivers share: the chain, the clock/cost model, the
/// §6.3 event counters and the memory registrations for per-snapshot
/// structures.
///
/// The driver is single-owner by design — one worker thread per VM holds
/// it exclusively (`&mut self` request paths) — so the lookup histogram
/// and the vectored-I/O counters are plain fields, not locked ones;
/// readers go through `&self` accessors that clone/copy.
pub struct DriverBase {
    pub chain: Chain,
    pub clock: Arc<VirtClock>,
    pub cost: CostModel,
    pub counters: Arc<CacheCounters>,
    pub lookup_hist: Histogram,
    pub acct: Arc<MemoryAccountant>,
    /// Write intercept shared with a live block job, if one is running
    /// (see [`crate::blockjob`]): guest writes mark clusters as newer
    /// than the job; job moves mark cached mappings as possibly stale.
    pub fence: Arc<JobFence>,
    /// Fetch-path scratch buffers (see [`SliceScratch`]).
    pub scratch: SliceScratch,
    /// Device reads that merged >= 2 cluster segments into one seek.
    pub merged_ios: u64,
    /// Bytes carried by those merged reads.
    pub coalesced_bytes: u64,
    /// One registration per image: driver struct + in-RAM L1 mirror.
    mem: Vec<Registration>,
}

impl DriverBase {
    pub fn new(chain: Chain, clock: Arc<VirtClock>, cost: CostModel, acct: Arc<MemoryAccountant>) -> Self {
        let mut mem = Vec::new();
        for img in chain.images() {
            mem.push(acct.register(MemCategory::DriverState, DRIVER_STATE_BYTES));
            mem.push(acct.register(MemCategory::L1Table, img.l1_bytes()));
        }
        DriverBase {
            chain,
            clock,
            cost,
            counters: Arc::new(CacheCounters::new()),
            lookup_hist: Histogram::new(),
            acct,
            fence: Arc::new(JobFence::default()),
            scratch: SliceScratch::default(),
            merged_ios: 0,
            coalesced_bytes: 0,
            mem,
        }
    }

    /// Re-register per-snapshot memory after the chain changed shape.
    pub fn refresh_mem(&mut self) {
        self.mem.clear();
        for img in self.chain.images() {
            self.mem
                .push(self.acct.register(MemCategory::DriverState, DRIVER_STATE_BYTES));
            self.mem
                .push(self.acct.register(MemCategory::L1Table, img.l1_bytes()));
        }
    }

    /// Charge one in-RAM cache probe (T_M).
    pub fn charge_ram(&self) {
        self.clock.advance(self.cost.ram_ns());
    }

    /// Charge one chain hop (Eq. 1's T_F): the Qemu call chain that moves
    /// resolution to the next backing file after a miss / hit-unallocated
    /// ("a set of function calls", Fig 3) — software-layer cost, ~T_L.
    pub fn charge_hop(&self) {
        self.clock.advance(self.cost.t_layers);
    }

    /// Record a resolve latency sample (plain field: the worker thread is
    /// the single owner, no lock on the hot path).
    pub fn record_lookup(&mut self, ns: u64) {
        self.lookup_hist.record(ns);
    }

    /// Clone of the lookup-latency distribution for readers (Fig 14).
    pub fn lookup_latency(&self) -> Histogram {
        self.lookup_hist.clone()
    }

    /// Read guest data for one resolved cluster segment; zero-fills holes.
    pub fn read_segment(
        &self,
        resolved: Option<(u16, u64)>,
        within: u64,
        buf: &mut [u8],
    ) -> Result<()> {
        match resolved {
            None => {
                buf.fill(0);
                Ok(())
            }
            Some((bfi, off)) => {
                let img = self
                    .chain
                    .get(bfi)
                    .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
                img.read_data(off, within, buf)
            }
        }
    }

    /// Copy-on-write into the active volume: allocate a cluster, copy the
    /// old content (if any), apply the sub-write, and persist the L2
    /// entry (write-through, "both on disk and in the cache", §2).
    /// Returns the new host offset in the active volume.
    pub fn cow_write(
        &self,
        vcluster: u64,
        old: Option<(u16, u64)>,
        within: u64,
        data: &[u8],
    ) -> Result<u64> {
        let active = self.chain.active();
        let cs = active.geom().cluster_size() as usize;
        let new_off = active.alloc_data_cluster()?;
        match old {
            Some((bfi, off)) if bfi != active.chain_index() => {
                // full-cluster copy from the owning backing file
                let src = self
                    .chain
                    .get(bfi)
                    .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
                let mut tmp = vec![0u8; cs];
                src.read_data(off, 0, &mut tmp)?;
                tmp[within as usize..within as usize + data.len()]
                    .copy_from_slice(data);
                active.write_data(new_off, 0, &tmp)?;
            }
            _ => {
                active.write_data(new_off, within, data)?;
            }
        }
        let stamp = if active.has_bfi() {
            Some(active.chain_index())
        } else {
            None
        };
        active.set_l2_entry(vcluster, L2Entry::local(new_off, stamp))?;
        Ok(new_off)
    }

    /// Split a byte range into (vcluster, offset-within, length) segments.
    /// Single-cluster requests (the common 4 KiB case) avoid the Vec
    /// (§Perf: ~10% of a warm read was this allocation).
    pub fn segments(&self, voff: u64, len: usize) -> SegmentIter {
        let geom = *self.chain.active().geom();
        SegmentIter { cs: geom.cluster_size(), bits: geom.cluster_bits, pos: voff, end: voff + len as u64 }
    }

    /// Split a scatter-gather request list into cluster segments, in iov
    /// order. Each iov's buffer is partitioned exactly by its segments.
    pub fn vsegments(&self, iovs: &[(u64, &mut [u8])]) -> Vec<VSeg> {
        let mut segs = Vec::new();
        for (i, (voff, buf)) in iovs.iter().enumerate() {
            for (vc, within, len) in self.segments(*voff, buf.len()) {
                segs.push(VSeg { iov: i, len, vc, within });
            }
        }
        segs
    }

    /// The contiguity coalescer: serve resolved segments with ONE device
    /// read per maximal physically contiguous same-file run; holes
    /// zero-fill. `resolved[i]` is segment `i`'s `(bfi, cluster host
    /// offset)` mapping. Sequential reads on a warm chain collapse from
    /// one device I/O per cluster to one per run.
    pub fn read_resolved(
        &mut self,
        segs: &[VSeg],
        resolved: &[Option<(u16, u64)>],
        iovs: &mut [(u64, &mut [u8])],
    ) -> Result<()> {
        debug_assert_eq!(segs.len(), resolved.len());
        // carve every iov buffer into per-segment destination slices
        // (segments were generated in iov order and cover each buffer)
        let mut dests: Vec<&mut [u8]> = Vec::with_capacity(segs.len());
        let mut k = 0usize;
        for (i, (_voff, buf)) in iovs.iter_mut().enumerate() {
            let mut rest: &mut [u8] = buf;
            while k < segs.len() && segs[k].iov == i {
                let (head, tail) = rest.split_at_mut(segs[k].len);
                dests.push(head);
                rest = tail;
                k += 1;
            }
            debug_assert!(rest.is_empty(), "segments must cover the buffer");
        }
        let mut i = 0usize;
        while i < segs.len() {
            let Some((bfi, off)) = resolved[i] else {
                dests[i].fill(0);
                i += 1;
                continue;
            };
            // grow the run while the next segment continues the same
            // file's physical byte range
            let run_start = off + segs[i].within;
            let mut run_end = run_start + segs[i].len as u64;
            let mut j = i + 1;
            while j < segs.len() {
                match resolved[j] {
                    Some((b2, o2)) if b2 == bfi && o2 + segs[j].within == run_end => {
                        run_end += segs[j].len as u64;
                        j += 1;
                    }
                    _ => break,
                }
            }
            let img = self
                .chain
                .get(bfi)
                .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
            if j == i + 1 {
                // lone segment: the existing single-cluster path
                img.read_data(off, segs[i].within, dests[i])?;
            } else {
                let mut run_bufs: Vec<&mut [u8]> =
                    dests[i..j].iter_mut().map(std::mem::take).collect();
                img.read_run_vectored(run_start, &mut run_bufs)?;
                self.merged_ios += 1;
                self.coalesced_bytes += run_end - run_start;
            }
            i = j;
        }
        Ok(())
    }
}

/// A cluster segment of a vectored request: the next `len` bytes of iov
/// `iov` map to virtual cluster `vc` at offset `within` (segments of an
/// iov partition its buffer in order).
#[derive(Clone, Copy, Debug)]
pub struct VSeg {
    pub iov: usize,
    pub len: usize,
    pub vc: u64,
    pub within: u64,
}

/// Partition `segs` into consecutive runs sharing one slice key
/// (`vc / slice_entries`) and resolve each run with `resolve_group` —
/// the shared grouping loop of both drivers' `readv`.
pub fn resolve_grouped(
    segs: &[VSeg],
    slice_entries: u64,
    mut resolve_group: impl FnMut(&[VSeg], u64, &mut Vec<Option<(u16, u64)>>) -> Result<()>,
) -> Result<Vec<Option<(u16, u64)>>> {
    let mut resolved = Vec::with_capacity(segs.len());
    let mut i = 0usize;
    while i < segs.len() {
        let key = segs[i].vc / slice_entries;
        let mut j = i + 1;
        while j < segs.len() && segs[j].vc / slice_entries == key {
            j += 1;
        }
        resolve_group(&segs[i..j], key, &mut resolved)?;
        i = j;
    }
    Ok(resolved)
}

/// Iterator over (vcluster, offset-within-cluster, length) segments.
pub struct SegmentIter {
    cs: u64,
    bits: u32,
    pos: u64,
    end: u64,
}

impl Iterator for SegmentIter {
    type Item = (u64, u64, usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.end {
            return None;
        }
        let vc = self.pos >> self.bits;
        let within = self.pos & (self.cs - 1);
        let n = ((self.cs - within) as usize).min((self.end - self.pos) as usize);
        self.pos += n as u64;
        Some((vc, within, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::CostModel;
    use crate::qcow::image::{DataMode, Image};
    use crate::qcow::layout::Geometry;
    use crate::storage::node::StorageNode;

    fn base() -> DriverBase {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            0,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        DriverBase::new(
            Chain::new(Arc::new(img)).unwrap(),
            clock,
            CostModel::default(),
            MemoryAccountant::new(),
        )
    }

    #[test]
    fn segments_split_on_cluster_boundaries() {
        let b = base();
        let cs = 64 << 10;
        let segs: Vec<_> = b.segments(cs - 10, 20).collect();
        assert_eq!(segs, vec![(0, cs - 10, 10), (1, 0, 10)]);
        let segs: Vec<_> = b.segments(0, 3 * cs as usize).collect();
        assert_eq!(segs.len(), 3);
        assert!(segs.iter().all(|&(_, w, n)| w == 0 && n == cs as usize));
    }

    #[test]
    fn cow_preserves_rest_of_cluster() {
        let b = base();
        // populate cluster 0 in the (single-image) chain
        let img = b.chain.active();
        let off = img.alloc_data_cluster().unwrap();
        let mut content = vec![0xAAu8; 64 << 10];
        content[100] = 1;
        img.write_data(off, 0, &content).unwrap();
        img.set_l2_entry(0, L2Entry::local(off, None)).unwrap();
        // unallocated target: fresh cluster, sub-write only, rest zeroed
        let new_off = b.cow_write(1, None, 50, &[9, 9]).unwrap();
        let mut back = vec![0u8; 3];
        img.read_data(new_off, 49, &mut back).unwrap();
        assert_eq!(back, [0, 9, 9]);
        assert_ne!(new_off, off);
    }

    #[test]
    fn memory_registered_per_image() {
        let b = base();
        assert_eq!(
            b.acct.live(MemCategory::DriverState),
            DRIVER_STATE_BYTES
        );
        assert!(b.acct.live(MemCategory::L1Table) > 0);
    }
}
