//! State and helpers shared by both drivers.

use crate::blockjob::JobFence;
use crate::dedup::{content_hash, CapacityPolicy};
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CacheCounters;
use crate::metrics::histogram::Histogram;
use crate::metrics::memory::{MemCategory, MemoryAccountant, Registration};
use crate::qcow::entry::{decode_offset, ClusterLoc, L2Entry, DESC_MASK};
use crate::qcow::image::DataMode;
use crate::qcow::Chain;
use anyhow::Result;
use std::sync::Arc;

/// The shared zero page: every hole and every `OFLAG_ZERO` cluster read
/// is served by copying from this one read-only buffer — no per-cluster
/// zero materialization and no device I/O (zero clusters bill zero
/// device time). Sized for the largest legal cluster (cluster_bits 21).
pub static ZERO_PAGE: [u8; 1 << 21] = [0u8; 1 << 21];

/// Serve `buf` from the shared zero page.
pub fn zero_fill(buf: &mut [u8]) {
    for chunk in buf.chunks_mut(ZERO_PAGE.len()) {
        chunk.copy_from_slice(&ZERO_PAGE[..chunk.len()]);
    }
}

/// What a policy-routed full-cluster write left behind: the mapping in
/// chain frame (`bfi`, offset word with descriptor bits) plus the raw L2
/// entry as persisted in the active table, so each driver can mirror it
/// into its own cache representation.
#[derive(Clone, Copy, Debug)]
pub struct WriteOutcome {
    pub bfi: u16,
    pub word: u64,
    pub entry: L2Entry,
}

/// Per-snapshot driver state a hypervisor keeps besides the caches (BDS,
/// AIO rings, refcount caches, throttling state, ...) — §4.3 found these
/// contribute a smaller but chain-length-proportional footprint in BOTH
/// designs ("sQEMU's memory overhead still slightly increases with the
/// chain size ... due to other per-snapshot data structures", §6.2).
/// Calibrated to Fig 12's sqemu residue: ~0.2 MiB per snapshot.
pub const DRIVER_STATE_BYTES: u64 = 200 << 10;

/// Reusable fetch-path scratch: the raw slice bytes and decoded entries
/// of the most recent cache-miss fetch (§Perf: one scratch pair reused
/// across all misses instead of two allocations per miss).
#[derive(Default)]
pub struct SliceScratch {
    pub raw: Vec<u8>,
    pub entries: Vec<u64>,
}

/// Everything both drivers share: the chain, the clock/cost model, the
/// §6.3 event counters and the memory registrations for per-snapshot
/// structures.
///
/// The driver is single-owner by design — one worker thread per VM holds
/// it exclusively (`&mut self` request paths) — so the lookup histogram
/// and the vectored-I/O counters are plain fields, not locked ones;
/// readers go through `&self` accessors that clone/copy.
pub struct DriverBase {
    pub chain: Chain,
    pub clock: Arc<VirtClock>,
    pub cost: CostModel,
    pub counters: Arc<CacheCounters>,
    pub lookup_hist: Histogram,
    pub acct: Arc<MemoryAccountant>,
    /// Write intercept shared with a live block job, if one is running
    /// (see [`crate::blockjob`]): guest writes mark clusters as newer
    /// than the job; job moves mark cached mappings as possibly stale.
    pub fence: Arc<JobFence>,
    /// Fetch-path scratch buffers (see [`SliceScratch`]).
    pub scratch: SliceScratch,
    /// Device reads that merged >= 2 cluster segments into one seek.
    pub merged_ios: u64,
    /// Bytes carried by those merged reads.
    pub coalesced_bytes: u64,
    /// Capacity subsystem switches (zero detection / compression /
    /// dedup). Default: everything off — the write path is bit-for-bit
    /// the pre-subsystem one.
    pub policy: CapacityPolicy,
    /// One registration per image: driver struct + in-RAM L1 mirror.
    mem: Vec<Registration>,
}

impl DriverBase {
    pub fn new(chain: Chain, clock: Arc<VirtClock>, cost: CostModel, acct: Arc<MemoryAccountant>) -> Self {
        let mut mem = Vec::new();
        for img in chain.images() {
            mem.push(acct.register(MemCategory::DriverState, DRIVER_STATE_BYTES));
            mem.push(acct.register(MemCategory::L1Table, img.l1_bytes()));
        }
        DriverBase {
            chain,
            clock,
            cost,
            counters: Arc::new(CacheCounters::new()),
            lookup_hist: Histogram::new(),
            acct,
            fence: Arc::new(JobFence::default()),
            scratch: SliceScratch::default(),
            merged_ios: 0,
            coalesced_bytes: 0,
            policy: CapacityPolicy::default(),
            mem,
        }
    }

    /// Re-register per-snapshot memory after the chain changed shape.
    pub fn refresh_mem(&mut self) {
        self.mem.clear();
        for img in self.chain.images() {
            self.mem
                .push(self.acct.register(MemCategory::DriverState, DRIVER_STATE_BYTES));
            self.mem
                .push(self.acct.register(MemCategory::L1Table, img.l1_bytes()));
        }
    }

    /// Charge one in-RAM cache probe (T_M).
    pub fn charge_ram(&self) {
        self.clock.advance(self.cost.ram_ns());
    }

    /// Charge one chain hop (Eq. 1's T_F): the Qemu call chain that moves
    /// resolution to the next backing file after a miss / hit-unallocated
    /// ("a set of function calls", Fig 3) — software-layer cost, ~T_L.
    pub fn charge_hop(&self) {
        self.clock.advance(self.cost.t_layers);
    }

    /// Charge the CPU cost of decompressing `bytes` of cluster data: the
    /// codec is a single linear pass, modeled as one RAM touch (T_M) per
    /// 4 KiB of decompressed output. The device read itself was billed at
    /// the *compressed* length by the timed backend — compression saves
    /// wire and disk time but is not free on the CPU.
    pub fn charge_decompress(&self, bytes: u64) {
        self.clock.advance(self.cost.ram_ns() * (bytes >> 12).max(1));
    }

    /// Record a resolve latency sample (plain field: the worker thread is
    /// the single owner, no lock on the hot path).
    pub fn record_lookup(&mut self, ns: u64) {
        self.lookup_hist.record(ns);
    }

    /// Clone of the lookup-latency distribution for readers (Fig 14).
    pub fn lookup_latency(&self) -> Histogram {
        self.lookup_hist.clone()
    }

    /// Read guest data for one resolved cluster segment. Holes and
    /// `OFLAG_ZERO` clusters are served from the shared zero page with
    /// zero device time; compressed clusters cost one device read of the
    /// compressed payload plus the modeled decompress pass.
    pub fn read_segment(
        &self,
        resolved: Option<(u16, u64)>,
        within: u64,
        buf: &mut [u8],
    ) -> Result<()> {
        let Some((bfi, word)) = resolved else {
            zero_fill(buf);
            return Ok(());
        };
        let img = self
            .chain
            .get(bfi)
            .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
        match decode_offset(word) {
            ClusterLoc::Data(off) => img.read_data(off, within, buf),
            ClusterLoc::Zero => {
                zero_fill(buf);
                Ok(())
            }
            ClusterLoc::Compressed { off, units } => {
                let cs = img.geom().cluster_size() as usize;
                let mut tmp = vec![0u8; cs];
                img.read_compressed(off, units, &mut tmp)?;
                self.charge_decompress(cs as u64);
                let w = within as usize;
                buf.copy_from_slice(&tmp[w..w + buf.len()]);
                Ok(())
            }
        }
    }

    /// Copy-on-write into the active volume: allocate a cluster, copy the
    /// old content (if any), apply the sub-write, and persist the L2
    /// entry (write-through, "both on disk and in the cache", §2).
    /// Returns the new host offset in the active volume.
    ///
    /// The old mapping may be any storage class: a plain cluster is
    /// copied from its owner (a backing file, or the active volume itself
    /// when the cluster is dedup-shared and thus not in-place writable),
    /// a compressed cluster is decompressed into the copy, and an
    /// `OFLAG_ZERO` cluster contributes zeros without touching the
    /// device. Active-owned old storage is freed afterwards — after the
    /// new entry is persisted, so a crash in between never leaves the
    /// entry pointing at freed storage.
    pub fn cow_write(
        &self,
        vcluster: u64,
        old: Option<(u16, u64)>,
        within: u64,
        data: &[u8],
    ) -> Result<u64> {
        let active = self.chain.active();
        let cs = active.geom().cluster_size() as usize;
        let new_off = active.alloc_data_cluster()?;
        match old.map(|(bfi, w)| (bfi, decode_offset(w))) {
            Some((bfi, ClusterLoc::Data(off))) => {
                // full-cluster copy from the owning file
                let src = self
                    .chain
                    .get(bfi)
                    .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
                let mut tmp = vec![0u8; cs];
                src.read_data(off, 0, &mut tmp)?;
                tmp[within as usize..within as usize + data.len()]
                    .copy_from_slice(data);
                active.write_data(new_off, 0, &tmp)?;
            }
            Some((bfi, ClusterLoc::Compressed { off, units })) => {
                let src = self
                    .chain
                    .get(bfi)
                    .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
                let mut tmp = vec![0u8; cs];
                src.read_compressed(off, units, &mut tmp)?;
                self.charge_decompress(cs as u64);
                tmp[within as usize..within as usize + data.len()]
                    .copy_from_slice(data);
                active.write_data(new_off, 0, &tmp)?;
            }
            // holes and zero clusters: fresh cluster, sub-write only —
            // the rest of the cluster reads back zero
            None | Some((_, ClusterLoc::Zero)) => {
                active.write_data(new_off, within, data)?;
            }
        }
        let stamp = if active.has_bfi() {
            Some(active.chain_index())
        } else {
            None
        };
        active.set_l2_entry(vcluster, L2Entry::local(new_off, stamp))?;
        self.release_overwritten(old)?;
        Ok(new_off)
    }

    /// The mapping `old` was just replaced by a new one: drop its dedup
    /// ledger reference and, when the active volume owned the storage,
    /// free it. Plain and compressed clusters are refcounted, so a
    /// dedup-shared cluster survives until its last sharer is gone.
    /// Remote storage (a backing file's cluster) is never freed here —
    /// backing files are immutable and GC-owned.
    fn release_overwritten(&self, old: Option<(u16, u64)>) -> Result<()> {
        let Some((bfi, word)) = old else {
            return Ok(());
        };
        let active = self.chain.active();
        if let Some(ctx) = &self.policy.dedup {
            if let Some(owner) = self.chain.get(bfi) {
                ctx.index.release(&ctx.node, &owner.name, word);
            }
        }
        if bfi != active.chain_index() {
            return Ok(());
        }
        match decode_offset(word) {
            ClusterLoc::Zero => Ok(()),
            ClusterLoc::Compressed { off, .. } => active.free_compressed(off),
            ClusterLoc::Data(off) => active.free_cluster(off),
        }
    }

    /// May a resolved active-owned mapping be overwritten in place? Only
    /// a plain (descriptor-free) cluster that is not dedup-shared: zero
    /// and compressed entries have no in-place bytes, and writing into a
    /// refcount-shared cluster would corrupt every other sharer.
    pub fn can_write_in_place(&self, word: u64) -> Result<bool> {
        if word & DESC_MASK != 0 {
            return Ok(false);
        }
        if self.policy.dedup.is_none() {
            return Ok(true);
        }
        Ok(self.chain.active().cluster_refcount(word)? == 1)
    }

    /// An in-place overwrite is about to change the bytes at `word` in
    /// the active volume: the content no longer matches any extent
    /// declared there, so withdraw it from future sharing.
    pub fn note_inplace_write(&self, word: u64) {
        if let Some(ctx) = &self.policy.dedup {
            ctx.index.retire(&ctx.node, &self.chain.active().name, word);
        }
    }

    /// Position of `file` in this chain, if present (dedup may only
    /// share extents stored in files the chain can address).
    fn chain_position(&self, file: &str) -> Option<u16> {
        self.chain
            .images()
            .iter()
            .position(|i| i.name == file)
            .map(|p| p as u16)
    }

    /// A full-cluster write routed through the capacity policy: zero
    /// detection, then dedup, then compression — falling back to the
    /// plain in-place / CoW path. Only called when `policy.any_enabled()`
    /// and the segment covers a whole cluster. Returns the mapping
    /// written so the driver can mirror it into its cache.
    ///
    /// `remote_shares` says whether this driver resolves stamped remote
    /// references (SQEMU). A remote dedup share points at a *different*
    /// virtual cluster's storage in a backing file, so only a
    /// stamp-honoring driver may create one; the vanilla driver passes
    /// `false` and dedups within the active volume only.
    pub fn full_cluster_write(
        &self,
        vcluster: u64,
        old: Option<(u16, u64)>,
        data: &[u8],
        remote_shares: bool,
    ) -> Result<WriteOutcome> {
        let active = self.chain.active();
        let own = active.chain_index();
        let cs = active.geom().cluster_size();
        debug_assert_eq!(data.len() as u64, cs);
        let stamp = if active.has_bfi() { Some(own) } else { None };
        let real = active.data_mode() == DataMode::Real;

        // 1) zero detection: an all-zero write allocates nothing — just
        // a deviceless OFLAG_ZERO entry (works in both data modes)
        if self.policy.zero_detect && data.iter().all(|&b| b == 0) {
            let e = L2Entry::zero_cluster(stamp);
            active.set_l2_entry(vcluster, e)?;
            self.release_overwritten(old)?;
            return Ok(WriteOutcome { bfi: own, word: e.host_offset(), entry: e });
        }

        // content hash, computed once on the raw bytes (so compressed
        // extents are shared by their uncompressed content)
        let hash = match (&self.policy.dedup, real) {
            (Some(_), true) => Some(content_hash(data)),
            _ => None,
        };

        // 2) dedup: the same bytes already stored on this node, in a
        // file this chain can address
        if let (Some(ctx), Some(h)) = (&self.policy.dedup, hash) {
            if let Some(ext) = ctx.index.lookup(&ctx.node, h) {
                let pos = self
                    .chain_position(&ext.file)
                    // a remote share needs both a stamp-honoring driver
                    // and a stamped active volume to record it in
                    .filter(|&p| p == own || (remote_shares && active.has_bfi()));
                if let Some(pos) = pos {
                    if matches!(old, Some((b, w)) if b == pos && w == ext.word) {
                        // rewriting identical bytes over the extent the
                        // entry already references: nothing to do
                        let entry = active.l2_entry(vcluster)?;
                        return Ok(WriteOutcome { bfi: pos, word: ext.word, entry });
                    }
                    let entry = if pos == own {
                        // local share: the cluster gains an on-disk
                        // refcount BEFORE the entry references it
                        // (refcount before reference, §10)
                        active.incref_cluster(ext.word & !DESC_MASK)?;
                        L2Entry::local(ext.word, stamp)
                    } else {
                        // share into an immutable backing file of this
                        // chain: file-level GC refcounts keep the file
                        // alive, no per-cluster incref needed
                        L2Entry::remote(ext.word, pos)
                    };
                    ctx.index.share(&ctx.node, h, cs);
                    active.set_l2_entry(vcluster, entry)?;
                    self.release_overwritten(old)?;
                    return Ok(WriteOutcome { bfi: pos, word: ext.word, entry });
                }
            }
        }

        // 3) compression: store the cluster as a sub-cluster payload if
        // it actually shrinks
        if self.policy.compress && real {
            if let Some(word) = active.write_compressed(data)? {
                let e = L2Entry::local(word, stamp);
                active.set_l2_entry(vcluster, e)?;
                self.release_overwritten(old)?;
                if let (Some(ctx), Some(h)) = (&self.policy.dedup, hash) {
                    ctx.index.declare(&ctx.node, h, &active.name, word);
                }
                return Ok(WriteOutcome { bfi: own, word, entry: e });
            }
        }

        // 4) plain path: in-place when the active volume owns a private
        // plain cluster, CoW otherwise — then declare the new content
        match old {
            Some((bfi, word)) if bfi == own && self.can_write_in_place(word)? => {
                self.note_inplace_write(word);
                active.write_data(word, 0, data)?;
                if let (Some(ctx), Some(h)) = (&self.policy.dedup, hash) {
                    ctx.index.declare(&ctx.node, h, &active.name, word);
                }
                Ok(WriteOutcome { bfi: own, word, entry: L2Entry::local(word, stamp) })
            }
            other => {
                let new_off = self.cow_write(vcluster, other, 0, data)?;
                if let (Some(ctx), Some(h)) = (&self.policy.dedup, hash) {
                    ctx.index.declare(&ctx.node, h, &active.name, new_off);
                }
                Ok(WriteOutcome {
                    bfi: own,
                    word: new_off,
                    entry: L2Entry::local(new_off, stamp),
                })
            }
        }
    }

    /// Split a byte range into (vcluster, offset-within, length) segments.
    /// Single-cluster requests (the common 4 KiB case) avoid the Vec
    /// (§Perf: ~10% of a warm read was this allocation).
    pub fn segments(&self, voff: u64, len: usize) -> SegmentIter {
        let geom = *self.chain.active().geom();
        SegmentIter { cs: geom.cluster_size(), bits: geom.cluster_bits, pos: voff, end: voff + len as u64 }
    }

    /// Split a scatter-gather request list into cluster segments, in iov
    /// order. Each iov's buffer is partitioned exactly by its segments.
    pub fn vsegments(&self, iovs: &[(u64, &mut [u8])]) -> Vec<VSeg> {
        let mut segs = Vec::new();
        for (i, (voff, buf)) in iovs.iter().enumerate() {
            for (vc, within, len) in self.segments(*voff, buf.len()) {
                segs.push(VSeg { iov: i, len, vc, within });
            }
        }
        segs
    }

    /// The contiguity coalescer: serve resolved segments with ONE device
    /// read per maximal physically contiguous same-file run; holes
    /// zero-fill. `resolved[i]` is segment `i`'s `(bfi, cluster host
    /// offset)` mapping. Sequential reads on a warm chain collapse from
    /// one device I/O per cluster to one per run.
    pub fn read_resolved(
        &mut self,
        segs: &[VSeg],
        resolved: &[Option<(u16, u64)>],
        iovs: &mut [(u64, &mut [u8])],
    ) -> Result<()> {
        debug_assert_eq!(segs.len(), resolved.len());
        // carve every iov buffer into per-segment destination slices
        // (segments were generated in iov order and cover each buffer)
        let mut dests: Vec<&mut [u8]> = Vec::with_capacity(segs.len());
        let mut k = 0usize;
        for (i, (_voff, buf)) in iovs.iter_mut().enumerate() {
            let mut rest: &mut [u8] = buf;
            while k < segs.len() && segs[k].iov == i {
                let (head, tail) = rest.split_at_mut(segs[k].len);
                dests.push(head);
                rest = tail;
                k += 1;
            }
            debug_assert!(rest.is_empty(), "segments must cover the buffer");
        }
        let mut i = 0usize;
        while i < segs.len() {
            let Some((bfi, word)) = resolved[i] else {
                // hole: the shared zero page, no device I/O
                zero_fill(dests[i]);
                i += 1;
                continue;
            };
            let off = match decode_offset(word) {
                ClusterLoc::Data(off) => off,
                ClusterLoc::Zero => {
                    // OFLAG_ZERO: shared zero page, zero device time
                    zero_fill(dests[i]);
                    i += 1;
                    continue;
                }
                ClusterLoc::Compressed { off, units } => {
                    let img = self
                        .chain
                        .get(bfi)
                        .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
                    let cs = img.geom().cluster_size() as usize;
                    let mut tmp = vec![0u8; cs];
                    img.read_compressed(off, units, &mut tmp)?;
                    self.charge_decompress(cs as u64);
                    let w = segs[i].within as usize;
                    dests[i].copy_from_slice(&tmp[w..w + segs[i].len]);
                    i += 1;
                    continue;
                }
            };
            // grow the run while the next segment continues the same
            // file's physical byte range with plain (descriptor-free)
            // clusters — special entries never join a device run
            let run_start = off + segs[i].within;
            let mut run_end = run_start + segs[i].len as u64;
            let mut j = i + 1;
            while j < segs.len() {
                match resolved[j] {
                    Some((b2, o2))
                        if b2 == bfi
                            && o2 & DESC_MASK == 0
                            && o2 + segs[j].within == run_end =>
                    {
                        run_end += segs[j].len as u64;
                        j += 1;
                    }
                    _ => break,
                }
            }
            let img = self
                .chain
                .get(bfi)
                .ok_or_else(|| anyhow::anyhow!("stamp to missing file {bfi}"))?;
            if j == i + 1 {
                // lone segment: the existing single-cluster path
                img.read_data(off, segs[i].within, dests[i])?;
            } else {
                let mut run_bufs: Vec<&mut [u8]> =
                    dests[i..j].iter_mut().map(std::mem::take).collect();
                img.read_run_vectored(run_start, &mut run_bufs)?;
                self.merged_ios += 1;
                self.coalesced_bytes += run_end - run_start;
            }
            i = j;
        }
        Ok(())
    }
}

/// A cluster segment of a vectored request: the next `len` bytes of iov
/// `iov` map to virtual cluster `vc` at offset `within` (segments of an
/// iov partition its buffer in order).
#[derive(Clone, Copy, Debug)]
pub struct VSeg {
    pub iov: usize,
    pub len: usize,
    pub vc: u64,
    pub within: u64,
}

/// Partition `segs` into consecutive runs sharing one slice key
/// (`vc / slice_entries`) and resolve each run with `resolve_group` —
/// the shared grouping loop of both drivers' `readv`.
pub fn resolve_grouped(
    segs: &[VSeg],
    slice_entries: u64,
    mut resolve_group: impl FnMut(&[VSeg], u64, &mut Vec<Option<(u16, u64)>>) -> Result<()>,
) -> Result<Vec<Option<(u16, u64)>>> {
    let mut resolved = Vec::with_capacity(segs.len());
    let mut i = 0usize;
    while i < segs.len() {
        let key = segs[i].vc / slice_entries;
        let mut j = i + 1;
        while j < segs.len() && segs[j].vc / slice_entries == key {
            j += 1;
        }
        resolve_group(&segs[i..j], key, &mut resolved)?;
        i = j;
    }
    Ok(resolved)
}

/// Iterator over (vcluster, offset-within-cluster, length) segments.
pub struct SegmentIter {
    cs: u64,
    bits: u32,
    pos: u64,
    end: u64,
}

impl Iterator for SegmentIter {
    type Item = (u64, u64, usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.end {
            return None;
        }
        let vc = self.pos >> self.bits;
        let within = self.pos & (self.cs - 1);
        let n = ((self.cs - within) as usize).min((self.end - self.pos) as usize);
        self.pos += n as u64;
        Some((vc, within, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::CostModel;
    use crate::qcow::image::{DataMode, Image};
    use crate::qcow::layout::Geometry;
    use crate::storage::node::StorageNode;

    fn base() -> DriverBase {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            0,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        DriverBase::new(
            Chain::new(Arc::new(img)).unwrap(),
            clock,
            CostModel::default(),
            MemoryAccountant::new(),
        )
    }

    #[test]
    fn segments_split_on_cluster_boundaries() {
        let b = base();
        let cs = 64 << 10;
        let segs: Vec<_> = b.segments(cs - 10, 20).collect();
        assert_eq!(segs, vec![(0, cs - 10, 10), (1, 0, 10)]);
        let segs: Vec<_> = b.segments(0, 3 * cs as usize).collect();
        assert_eq!(segs.len(), 3);
        assert!(segs.iter().all(|&(_, w, n)| w == 0 && n == cs as usize));
    }

    #[test]
    fn cow_preserves_rest_of_cluster() {
        let b = base();
        // populate cluster 0 in the (single-image) chain
        let img = b.chain.active();
        let off = img.alloc_data_cluster().unwrap();
        let mut content = vec![0xAAu8; 64 << 10];
        content[100] = 1;
        img.write_data(off, 0, &content).unwrap();
        img.set_l2_entry(0, L2Entry::local(off, None)).unwrap();
        // unallocated target: fresh cluster, sub-write only, rest zeroed
        let new_off = b.cow_write(1, None, 50, &[9, 9]).unwrap();
        let mut back = vec![0u8; 3];
        img.read_data(new_off, 49, &mut back).unwrap();
        assert_eq!(back, [0, 9, 9]);
        assert_ne!(new_off, off);
    }

    #[test]
    fn memory_registered_per_image() {
        let b = base();
        assert_eq!(
            b.acct.live(MemCategory::DriverState),
            DRIVER_STATE_BYTES
        );
        assert!(b.acct.live(MemCategory::L1Table) > 0);
    }

    #[test]
    fn cow_over_zero_cluster_preserves_zeros() {
        let b = base();
        let img = b.chain.active();
        img.set_l2_entry(0, L2Entry::zero_cluster(None)).unwrap();
        let old = b.chain.resolve_walk(0).unwrap();
        assert!(L2Entry(old.unwrap().1).is_zero_cluster());
        let new_off = b.cow_write(0, old, 100, &[5, 5]).unwrap();
        let mut back = vec![0u8; 4];
        img.read_data(new_off, 99, &mut back).unwrap();
        assert_eq!(back, [0, 5, 5, 0]);
        assert!(!img.l2_entry(0).unwrap().is_zero_cluster());
    }

    #[test]
    fn zero_detect_allocates_nothing_and_reads_zero() {
        let mut b = base();
        b.policy = CapacityPolicy { zero_detect: true, ..Default::default() };
        let img = b.chain.active();
        let cs = img.geom().cluster_size() as usize;
        let len_before = img.file_len();
        let out = b
            .full_cluster_write(3, None, &vec![0u8; cs], false)
            .unwrap();
        assert!(out.entry.is_zero_cluster());
        assert_eq!(b.chain.active().file_len(), len_before, "no allocation");
        let mut buf = vec![0xAAu8; 16];
        let resolved = b.chain.resolve_walk(3).unwrap();
        assert!(resolved.is_some(), "zero cluster is present");
        b.read_segment(resolved, 0, &mut buf).unwrap();
        assert_eq!(buf, vec![0u8; 16]);
    }

    #[test]
    fn dedup_local_share_refcounts_and_cow_on_overwrite() {
        use crate::dedup::DedupIndex;
        let mut b = base();
        let index = Arc::new(DedupIndex::new());
        b.policy = CapacityPolicy::full(Arc::clone(&index), "s");
        b.policy.compress = false; // isolate dedup
        let img = Arc::clone(b.chain.active());
        let cs = img.geom().cluster_size() as usize;
        // incompressible-ish distinct content, written twice
        let mut content = vec![0u8; cs];
        for (i, x) in content.iter_mut().enumerate() {
            *x = (i % 251) as u8;
        }
        let a = b.full_cluster_write(0, None, &content, false).unwrap();
        let c = b.full_cluster_write(1, None, &content, false).unwrap();
        assert_eq!(a.word, c.word, "second write shared the extent");
        assert_eq!(img.cluster_refcount(a.word).unwrap(), 2);
        assert_eq!(index.node_stats("s").saved_bytes, cs as u64);
        // a partial in-place overwrite of the shared cluster must CoW
        assert!(!b.can_write_in_place(a.word).unwrap());
        let old = b.chain.resolve_walk(0).unwrap();
        b.cow_write(0, old, 0, &[9u8; 4]).unwrap();
        assert_eq!(img.cluster_refcount(a.word).unwrap(), 1, "sharer left");
        // the surviving sharer still reads the original bytes
        let mut back = vec![0u8; cs];
        b.read_segment(b.chain.resolve_walk(1).unwrap(), 0, &mut back)
            .unwrap();
        assert_eq!(back, content);
    }

    #[test]
    fn compressed_write_round_trips_through_read_segment() {
        let mut b = base();
        b.policy = CapacityPolicy {
            compress: true,
            ..Default::default()
        };
        let img = b.chain.active();
        let cs = img.geom().cluster_size() as usize;
        let mut content = vec![0u8; cs];
        for (i, x) in content.iter_mut().enumerate() {
            *x = if i % 97 == 0 { 1 } else { 0x40 };
        }
        let out = b.full_cluster_write(2, None, &content, false).unwrap();
        assert!(out.entry.is_compressed());
        let mut back = vec![0u8; cs];
        b.read_segment(Some((out.bfi, out.word)), 0, &mut back).unwrap();
        assert_eq!(back, content);
    }
}
