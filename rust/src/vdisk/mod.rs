//! Virtual-disk drivers: the two request paths the paper compares.
//!
//! * [`vanilla::VanillaDriver`] — §2's recursive design: one L2 slice
//!   cache per backing file, chain walked file-by-file from the active
//!   volume ("Qemu manages a chain snapshot-by-snapshot").
//! * [`scalable::ScalableDriver`] — §5's SQEMU design: a single unified
//!   cache over the chain plus direct access to the owning backing file
//!   via the `backing_file_index` stamps; falls back to a
//!   correction-driven walk on unstamped (vanilla) images, preserving
//!   backward compatibility.
//!
//! Both implement [`Driver`] and must return byte-identical data for any
//! chain (`tests/driver_equivalence.rs`); they differ only in cost
//! structure (virtual time, event counters, memory footprint).

pub mod common;
pub mod scalable;
pub mod vanilla;

use crate::blockjob::JobFence;
use crate::dedup::CapacityPolicy;
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::histogram::Histogram;
use crate::qcow::Chain;
use anyhow::Result;
use std::sync::Arc;

/// Which request-path design a VM runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriverKind {
    /// vQemu: per-backing-file caches + recursive chain walk.
    Vanilla,
    /// SQEMU: unified cache + direct access (§5).
    Scalable,
}

impl DriverKind {
    pub fn name(self) -> &'static str {
        match self {
            DriverKind::Vanilla => "vqemu",
            DriverKind::Scalable => "sqemu",
        }
    }
}

/// Counters of the vectored request path (see `DriverBase`): device
/// reads that merged two or more cluster segments into one seek, and the
/// bytes those merged reads carried.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VecIoSnapshot {
    pub merged_ios: u64,
    pub coalesced_bytes: u64,
}

/// One operation of a ring submission, borrowing the caller's buffers
/// (the shard executor's completion-friendly submit surface).
#[derive(Debug)]
pub enum DiskOp<'a> {
    Read { voff: u64, buf: &'a mut [u8] },
    Write { voff: u64, data: &'a [u8] },
}

/// Outcome of [`Driver::submit`]: how many leading ops completed, and
/// the error that stopped the batch, if any.
#[derive(Debug)]
pub struct SubmitResult {
    /// Ops fully executed, in submission order (== `ops.len()` iff
    /// `error` is `None`).
    pub completed: usize,
    pub error: Option<anyhow::Error>,
}

/// A guest-facing block driver over a snapshot chain.
pub trait Driver: Send {
    /// Read `buf.len()` bytes at virtual offset `voff`. Unallocated
    /// ranges read as zeros.
    fn read(&mut self, voff: u64, buf: &mut [u8]) -> Result<()>;

    /// Write at virtual offset `voff` (copy-on-write into the active
    /// volume when the cluster is owned by a backing file).
    fn write(&mut self, voff: u64, data: &[u8]) -> Result<()>;

    /// Scatter-gather read: fill every `(voff, buf)` pair. Must be
    /// bit-identical to issuing the `read`s one by one (the vectored
    /// property tests enforce this). The default loops for compat; both
    /// in-tree drivers override it with batched slice resolution and
    /// run-coalesced device reads.
    fn readv(&mut self, iovs: &mut [(u64, &mut [u8])]) -> Result<()> {
        for iov in iovs.iter_mut() {
            self.read(iov.0, iov.1)?;
        }
        Ok(())
    }

    /// Gather write of every `(voff, data)` pair, in order. Must be
    /// bit-identical to issuing the `write`s one by one — writes keep
    /// per-cluster copy-on-write semantics (each cluster write may
    /// allocate), so the win is amortized submission, not merged device
    /// commands.
    fn writev(&mut self, iovs: &[(u64, &[u8])]) -> Result<()> {
        for (voff, data) in iovs {
            self.write(*voff, data)?;
        }
        Ok(())
    }

    /// Execute a mixed submission in order, grouping maximal runs of
    /// consecutive same-kind ops into one `readv`/`writev` so the
    /// vectored path's slice-batching and run-coalescing apply across a
    /// ring burst. Stops at the first failing group; `completed` counts
    /// the ops before it. Semantically identical to issuing the ops one
    /// by one (per-VM program order is the ring's contract).
    fn submit(&mut self, ops: &mut [DiskOp<'_>]) -> SubmitResult {
        let mut done = 0;
        while done < ops.len() {
            let read_group = matches!(ops[done], DiskOp::Read { .. });
            let mut end = done + 1;
            while end < ops.len()
                && matches!(ops[end], DiskOp::Read { .. }) == read_group
            {
                end += 1;
            }
            let res = if read_group {
                let mut iovs: Vec<(u64, &mut [u8])> = ops[done..end]
                    .iter_mut()
                    .map(|op| match op {
                        DiskOp::Read { voff, buf } => (*voff, &mut **buf),
                        DiskOp::Write { .. } => unreachable!("read group"),
                    })
                    .collect();
                self.readv(&mut iovs)
            } else {
                let iovs: Vec<(u64, &[u8])> = ops[done..end]
                    .iter()
                    .map(|op| match op {
                        DiskOp::Write { voff, data } => (*voff, &**data),
                        DiskOp::Read { .. } => unreachable!("write group"),
                    })
                    .collect();
                self.writev(&iovs)
            };
            match res {
                Ok(()) => done = end,
                Err(e) => {
                    return SubmitResult { completed: done, error: Some(e) }
                }
            }
        }
        SubmitResult { completed: done, error: None }
    }

    /// Write back all dirty cache slices.
    fn flush(&mut self) -> Result<()>;

    fn kind(&self) -> DriverKind;

    fn chain(&self) -> &Chain;

    /// Mutable access to the chain for paused-VM operations (snapshot,
    /// streaming). Callers must `flush()` first and `reopen()` after.
    fn chain_mut(&mut self) -> &mut Chain;

    /// Rebuild caches and per-snapshot state after the chain changed
    /// shape (snapshot appended a volume / streaming dropped files).
    fn reopen(&mut self) -> Result<()>;

    /// The write intercept a live block job shares with this driver
    /// (see [`crate::blockjob::JobFence`]). Inactive unless a job is
    /// running against this VM.
    fn fence(&self) -> &Arc<JobFence>;

    /// Low-level event counters (§6.3): hits, misses, hit-unallocated,
    /// per-file lookup distribution.
    fn counters(&self) -> CounterSnapshot;

    /// Distribution of cache lookup latencies in virtual ns (Fig 14).
    /// Batched resolution records one sample per slice group.
    fn lookup_latency(&self) -> Histogram;

    /// Vectored-path counters (merged device reads and their bytes).
    /// Default: zeros, for drivers without a coalescer.
    fn vec_io(&self) -> VecIoSnapshot {
        VecIoSnapshot::default()
    }

    /// Live cache bytes (for reports; the accountant tracks the total).
    fn cache_bytes(&self) -> u64;

    /// Enable/disable the capacity subsystem (zero detection,
    /// compression, dedup) for this VM's write path. Default: ignored —
    /// a driver that doesn't support the subsystem keeps the plain
    /// write path, which is always correct.
    fn set_capacity_policy(&mut self, _policy: CapacityPolicy) {}
}
