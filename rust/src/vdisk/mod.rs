//! Virtual-disk drivers: the two request paths the paper compares.
//!
//! * [`vanilla::VanillaDriver`] — §2's recursive design: one L2 slice
//!   cache per backing file, chain walked file-by-file from the active
//!   volume ("Qemu manages a chain snapshot-by-snapshot").
//! * [`scalable::ScalableDriver`] — §5's SQEMU design: a single unified
//!   cache over the chain plus direct access to the owning backing file
//!   via the `backing_file_index` stamps; falls back to a
//!   correction-driven walk on unstamped (vanilla) images, preserving
//!   backward compatibility.
//!
//! Both implement [`Driver`] and must return byte-identical data for any
//! chain (`tests/driver_equivalence.rs`); they differ only in cost
//! structure (virtual time, event counters, memory footprint).

pub mod common;
pub mod scalable;
pub mod vanilla;

use crate::blockjob::JobFence;
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::histogram::Histogram;
use crate::qcow::Chain;
use anyhow::Result;
use std::sync::Arc;

/// Which request-path design a VM runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriverKind {
    /// vQemu: per-backing-file caches + recursive chain walk.
    Vanilla,
    /// SQEMU: unified cache + direct access (§5).
    Scalable,
}

impl DriverKind {
    pub fn name(self) -> &'static str {
        match self {
            DriverKind::Vanilla => "vqemu",
            DriverKind::Scalable => "sqemu",
        }
    }
}

/// A guest-facing block driver over a snapshot chain.
pub trait Driver: Send {
    /// Read `buf.len()` bytes at virtual offset `voff`. Unallocated
    /// ranges read as zeros.
    fn read(&mut self, voff: u64, buf: &mut [u8]) -> Result<()>;

    /// Write at virtual offset `voff` (copy-on-write into the active
    /// volume when the cluster is owned by a backing file).
    fn write(&mut self, voff: u64, data: &[u8]) -> Result<()>;

    /// Write back all dirty cache slices.
    fn flush(&mut self) -> Result<()>;

    fn kind(&self) -> DriverKind;

    fn chain(&self) -> &Chain;

    /// Mutable access to the chain for paused-VM operations (snapshot,
    /// streaming). Callers must `flush()` first and `reopen()` after.
    fn chain_mut(&mut self) -> &mut Chain;

    /// Rebuild caches and per-snapshot state after the chain changed
    /// shape (snapshot appended a volume / streaming dropped files).
    fn reopen(&mut self) -> Result<()>;

    /// The write intercept a live block job shares with this driver
    /// (see [`crate::blockjob::JobFence`]). Inactive unless a job is
    /// running against this VM.
    fn fence(&self) -> &Arc<JobFence>;

    /// Low-level event counters (§6.3): hits, misses, hit-unallocated,
    /// per-file lookup distribution.
    fn counters(&self) -> CounterSnapshot;

    /// Distribution of cache lookup latencies in virtual ns (Fig 14).
    fn lookup_latency(&self) -> Histogram;

    /// Live cache bytes (for reports; the accountant tracks the total).
    fn cache_bytes(&self) -> u64;
}
