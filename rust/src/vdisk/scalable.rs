//! The SQEMU driver (§5): direct access + unified indexing cache.
//!
//! On a fully stamped chain a resolve is O(1) in chain length: one probe
//! of the unified cache; a miss fetches a single slice from the active
//! volume (whose table is complete after the §5.4 snapshot copy); an
//! entry stamped for a backing file is served directly from that file.
//!
//! On unstamped (vanilla) images the driver stays correct but degrades to
//! a correction-driven chain walk — the §5.1 backward-compatibility
//! story: "existing Qcow2 images lacking our format's metadata should
//! still work ... without performance/memory consumption gains".

use super::common::{resolve_grouped, DriverBase, VSeg};
use super::{Driver, DriverKind, VecIoSnapshot};
use crate::cache::unified::normalize;
use crate::cache::{CacheConfig, UnifiedCache};
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::histogram::Histogram;
use crate::metrics::memory::MemoryAccountant;
use crate::qcow::entry::L2Entry;
use crate::qcow::Chain;
use anyhow::Result;
use std::sync::Arc;

pub struct ScalableDriver {
    base: DriverBase,
    cache: UnifiedCache,
    cache_cfg: CacheConfig,
    /// Active volume's table is complete (all-sqemu chain): misses need
    /// only consult the active volume; `Some(None)` lookups are
    /// definitive holes.
    complete_index: bool,
}

impl ScalableDriver {
    pub fn new(
        chain: Chain,
        cache_cfg: CacheConfig,
        clock: Arc<VirtClock>,
        cost: CostModel,
        acct: Arc<MemoryAccountant>,
    ) -> Self {
        let active_index = (chain.len() - 1) as u16;
        // a single-image chain is trivially complete
        let complete_index = chain.active().has_bfi() || chain.len() == 1;
        let cache = UnifiedCache::new(cache_cfg, active_index, &acct);
        ScalableDriver {
            base: DriverBase::new(chain, clock, cost, acct),
            cache,
            cache_cfg,
            complete_index,
        }
    }

    /// Fetch the slice covering `vcluster` from file `from_idx` into the
    /// unified cache (insert on the first fetch, §5.3 correction
    /// otherwise) and return the authoritative chain-frame entry for
    /// `vcluster` — the miss path resolves from the fetch result itself,
    /// with no second cache probe (mirroring the PR-2 hit-path fix).
    /// `Ok(None)` = that file has no table for the range. The raw slice
    /// is decoded into the driver-owned scratch and normalized in place,
    /// so a miss costs no transient allocations.
    fn fetch_slice_from(&mut self, vcluster: u64, from_idx: u16) -> Result<Option<L2Entry>> {
        let cfg = *self.cache.cfg();
        let key = cfg.slice_key(vcluster);
        let idx_in_slice = cfg.slice_index(vcluster) as usize;
        let img = Arc::clone(
            self.base
                .chain
                .get(from_idx)
                .ok_or_else(|| anyhow::anyhow!("no file {from_idx}"))?,
        );
        let (l1_idx, _) = img.geom().split_vcluster(vcluster);
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            return Ok(None);
        }
        let slice_start = cfg.slice_base(key) % img.geom().entries_per_l2();
        img.read_l2_slice_into(
            l2_off,
            slice_start,
            cfg.slice_entries,
            &mut self.base.scratch.raw,
            &mut self.base.scratch.entries,
        )?;
        for e in self.base.scratch.entries.iter_mut() {
            *e = normalize(*e, from_idx);
        }
        if self.cache.contains(key) {
            let merged = self
                .cache
                .correct_normalized(key, &self.base.scratch.entries)
                .map(|(_, s)| L2Entry(s[idx_in_slice]))
                .expect("slice resident");
            Ok(Some(merged))
        } else {
            let entry = L2Entry(self.base.scratch.entries[idx_in_slice]);
            if let Some((ek, evicted)) =
                self.cache.insert_normalized(key, &self.base.scratch.entries)
            {
                self.writeback(ek, &evicted)?;
            }
            Ok(Some(entry))
        }
    }

    /// Insert an all-zero slice (active volume has no table for the range
    /// on a complete chain: definitive hole).
    fn insert_hole_slice(&mut self, vcluster: u64) -> Result<()> {
        let cfg = *self.cache.cfg();
        let key = cfg.slice_key(vcluster);
        self.base.scratch.entries.clear();
        self.base
            .scratch
            .entries
            .resize(cfg.slice_entries as usize, 0);
        if let Some((ek, evicted)) =
            self.cache.insert_normalized(key, &self.base.scratch.entries)
        {
            self.writeback(ek, &evicted)?;
        }
        Ok(())
    }

    /// §5.3 resolution.
    fn resolve(&mut self, vcluster: u64) -> Result<Option<(u16, u64)>> {
        let active_index = self.cache.active_index();
        self.base.counters.lookup_on(active_index as usize);
        self.base.charge_ram();
        // 1) probe the unified cache — one lookup on the hit path (§Perf:
        // the old contains+lookup double probe cost ~6% of a warm read)
        let looked = match self.cache.lookup(vcluster) {
            Some(view) => view,
            None => {
                // cache miss: one fetch from the active volume; the fetch
                // result doubles as the probe (no second lookup)
                let fetched = self.fetch_slice_from(vcluster, active_index)?;
                self.base.charge_ram(); // re-examine the cached entry (Fig 3 steps 5-6)
                match fetched {
                    Some(e) => {
                        self.base.counters.miss();
                        e.bfi().map(|b| (b, e.host_offset()))
                    }
                    None => {
                        // active volume has no table here: definitive hole
                        // on a complete chain; on a vanilla chain the
                        // correction walk below consults the backing files
                        self.insert_hole_slice(vcluster)?;
                        None
                    }
                }
            }
        };
        match looked {
            Some((bfi, off)) if bfi == active_index => {
                self.base.counters.hit();
                Ok(Some((bfi, off)))
            }
            Some((bfi, off)) => {
                // owned by a backing file: "cache hit unallocated" —
                // direct access, O(1) regardless of chain position (§5.3)
                self.base.counters.unallocated();
                self.base.charge_ram();
                Ok(Some((bfi, off)))
            }
            None if self.complete_index => Ok(None),
            None => {
                // backward-compat path: walk backing files with cache
                // correction until the entry resolves or the chain ends
                self.base.counters.unallocated();
                for idx in (0..active_index).rev() {
                    self.base.counters.lookup_on(idx as usize);
                    if let Some(e) = self.fetch_slice_from(vcluster, idx)? {
                        self.base.counters.miss();
                        self.base.charge_ram();
                        if let Some((bfi, off)) = e.bfi().map(|b| (b, e.host_offset())) {
                            self.base.counters.unallocated();
                            return Ok(Some((bfi, off)));
                        }
                        // present-but-unallocated entry: one Eq. 1 chain
                        // hop (T_F) down to the next backing file — the
                        // same call-chain cost VanillaDriver pays, so the
                        // compat walk is not free in the cost model
                        self.base.charge_hop();
                    }
                }
                Ok(None)
            }
        }
    }

    /// Batched §5.3 resolution for one slice group: every segment in
    /// `group` shares slice key `key`, so the whole group is resolved
    /// from ONE cache probe — one T_M charge and one histogram sample
    /// for the group, not one per cluster.
    fn resolve_group(
        &mut self,
        group: &[VSeg],
        key: u64,
        out: &mut Vec<Option<(u16, u64)>>,
    ) -> Result<()> {
        let cfg = *self.cache.cfg();
        let active_index = self.cache.active_index();
        let t0 = self.base.clock.now();
        self.base.counters.lookup_on(active_index as usize);
        self.base.charge_ram();
        if self.cache.lookup_slice(key).is_none() {
            // group miss: one fetch from the active volume covers every
            // cluster of the slice
            let fetched = self.fetch_slice_from(group[0].vc, active_index)?;
            self.base.charge_ram();
            match fetched {
                Some(_) => self.base.counters.miss(),
                None => self.insert_hole_slice(group[0].vc)?,
            }
        }
        let base_idx = out.len();
        let mut any_remote = false;
        let mut any_unresolved = false;
        {
            let entries = self.cache.lookup_slice(key).expect("slice resident");
            for s in group {
                let e = L2Entry(entries[cfg.slice_index(s.vc) as usize]);
                let view = e.bfi().map(|b| (b, e.host_offset()));
                match view {
                    Some((bfi, _)) if bfi == active_index => self.base.counters.hit(),
                    Some(_) => {
                        self.base.counters.unallocated();
                        any_remote = true;
                    }
                    None => any_unresolved = true,
                }
                out.push(view);
            }
        }
        if any_remote {
            // direct backing-file access: one amortized T_M per group
            self.base.charge_ram();
        }
        if any_unresolved && !self.complete_index {
            // backward-compat: unresolved clusters of an unstamped chain
            // fall back to the scalar correction walk
            for (k, s) in group.iter().enumerate() {
                if out[base_idx + k].is_none() {
                    out[base_idx + k] = self.resolve(s.vc)?;
                }
            }
        }
        // one histogram sample for the whole group — including any
        // compat-walk fallback, which dominates on unstamped chains
        let dt = self.base.clock.now() - t0;
        self.base.record_lookup(dt);
        Ok(())
    }

    fn writeback(&self, key: u64, entries: &[u64]) -> Result<()> {
        let active = self.base.chain.active();
        let cfg = self.cache.cfg();
        let vc = cfg.slice_base(key);
        let (l1_idx, _) = active.geom().split_vcluster(vc);
        let l2_off = active.ensure_l2(l1_idx)?;
        let slice_start = cfg.slice_base(key) % active.geom().entries_per_l2();
        active.write_l2_slice(l2_off, slice_start, entries)
    }
}

impl Driver for ScalableDriver {
    fn read(&mut self, voff: u64, buf: &mut [u8]) -> Result<()> {
        let mut cursor = 0usize;
        for (vc, within, len) in self.base.segments(voff, buf.len()) {
            let (resolved, dt) = {
                let t0 = self.base.clock.now();
                let r = self.resolve(vc)?;
                (r, self.base.clock.now() - t0)
            };
            self.base.record_lookup(dt);
            self.base
                .read_segment(resolved, within, &mut buf[cursor..cursor + len])?;
            cursor += len;
        }
        Ok(())
    }

    /// The vectored read path: segments of all iovs are resolved in
    /// slice groups (one unified-cache probe per group) and served
    /// through the [`DriverBase::read_resolved`] contiguity coalescer.
    fn readv(&mut self, iovs: &mut [(u64, &mut [u8])]) -> Result<()> {
        let segs = self.base.vsegments(iovs);
        let slice_entries = self.cache.cfg().slice_entries;
        let resolved = resolve_grouped(&segs, slice_entries, |g, k, out| {
            self.resolve_group(g, k, out)
        })?;
        self.base.read_resolved(&segs, &resolved, iovs)
    }

    fn write(&mut self, voff: u64, data: &[u8]) -> Result<()> {
        let active_index = self.cache.active_index();
        let cs = self.base.chain.active().geom().cluster_size();
        let mut cursor = 0usize;
        for (vc, within, len) in self.base.segments(voff, data.len()) {
            let (mut resolved, dt) = {
                let t0 = self.base.clock.now();
                let r = self.resolve(vc)?;
                (r, self.base.clock.now() - t0)
            };
            self.base.record_lookup(dt);
            // write intercept (live block jobs): mark this cluster as
            // newer than the job, and — if the job already relocated
            // it — bypass the (possibly stale) cached mapping. If a
            // stale writeback clobbered the job's on-disk entry, re-link
            // to the job's copy rather than trusting the clobbered
            // entry (a zero entry would zero-fill and lose data).
            self.base.fence.note_guest_write(vc);
            let job_moved = self.base.fence.job_moved(vc);
            if let Some(moved_off) = job_moved {
                let active = self.base.chain.active();
                resolved = match active.l2_entry(vc)?.sqemu_view(active_index) {
                    Some((bfi, off)) if bfi == active_index => Some((bfi, off)),
                    _ => {
                        let stamp = if active.has_bfi() {
                            Some(active_index)
                        } else {
                            None
                        };
                        active.set_l2_entry(vc, L2Entry::local(moved_off, stamp))?;
                        Some((active_index, moved_off))
                    }
                };
            }
            let chunk = &data[cursor..cursor + len];
            if within == 0 && len as u64 == cs && self.base.policy.any_enabled() {
                // full-cluster write through the capacity policy (zero
                // detection / dedup / compression, plain fallback)
                let out = self.base.full_cluster_write(vc, resolved, chunk, true)?;
                self.cache.record_entry(vc, out.bfi, out.word);
                cursor += len;
                continue;
            }
            match resolved {
                Some((bfi, off))
                    if bfi == active_index && self.base.can_write_in_place(off)? =>
                {
                    self.base.note_inplace_write(off);
                    self.base.chain.active().write_data(off, within, chunk)?;
                    if job_moved.is_some() {
                        // resync the cached entry with the bypassed
                        // on-disk mapping
                        self.cache.record_write(vc, off);
                    }
                }
                other => {
                    let new_off = self.base.cow_write(vc, other, within, chunk)?;
                    self.cache.record_write(vc, new_off);
                }
            }
            cursor += len;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        for (key, entries) in self.cache.drain() {
            self.writeback(key, &entries)?;
        }
        // durability barrier: flush acknowledges the guest's FLUSH — all
        // data and metadata written so far must survive a crash
        self.base.chain.active().flush()
    }

    fn kind(&self) -> DriverKind {
        DriverKind::Scalable
    }

    fn chain(&self) -> &Chain {
        &self.base.chain
    }

    fn chain_mut(&mut self) -> &mut Chain {
        &mut self.base.chain
    }

    fn reopen(&mut self) -> Result<()> {
        // drain before rebuilding: the cache may hold dirty corrected
        // slices due for writeback — rebuilding without flushing would
        // silently discard them (the callers that flush first make this
        // a no-op; direct reopens must not lose corrections)
        self.flush()?;
        let active_index = (self.base.chain.len() - 1) as u16;
        self.complete_index =
            self.base.chain.active().has_bfi() || self.base.chain.len() == 1;
        self.cache = UnifiedCache::new(self.cache_cfg, active_index, &self.base.acct);
        self.base.refresh_mem();
        Ok(())
    }

    fn fence(&self) -> &Arc<crate::blockjob::JobFence> {
        &self.base.fence
    }

    fn counters(&self) -> CounterSnapshot {
        self.base.counters.snapshot()
    }

    fn lookup_latency(&self) -> Histogram {
        self.base.lookup_latency()
    }

    fn vec_io(&self) -> VecIoSnapshot {
        VecIoSnapshot {
            merged_ios: self.base.merged_ios,
            coalesced_bytes: self.base.coalesced_bytes,
        }
    }

    fn cache_bytes(&self) -> u64 {
        self.cache.resident_bytes()
    }

    fn set_capacity_policy(&mut self, policy: crate::dedup::CapacityPolicy) {
        self.base.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qcow::entry::L2Entry;
    use crate::qcow::image::{DataMode, Image};
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::snapshot;
    use crate::storage::node::StorageNode;

    fn sq_chain(n_snapshots: usize) -> (Arc<StorageNode>, Chain, Arc<VirtClock>) {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 0..n_snapshots {
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[i as u8 + 1; 32]).unwrap();
            img.set_l2_entry(
                i as u64,
                L2Entry::local(off, Some(img.chain_index())),
            )
            .unwrap();
            snapshot::snapshot_sqemu(&mut chain, &node, &format!("img-{}", i + 1))
                .unwrap();
        }
        (node, chain, clock)
    }

    fn driver(chain: Chain, clock: Arc<VirtClock>) -> ScalableDriver {
        ScalableDriver::new(
            chain,
            CacheConfig::new(32, 1 << 20),
            clock,
            CostModel::default(),
            MemoryAccountant::new(),
        )
    }

    #[test]
    fn reads_layers_directly() {
        let (_n, chain, clock) = sq_chain(3);
        let mut d = driver(chain, clock);
        let cs = 64 << 10;
        let mut buf = [0u8; 4];
        for i in 0..3u64 {
            d.read(i * cs, &mut buf).unwrap();
            assert_eq!(buf, [i as u8 + 1; 4], "layer {i}");
        }
        d.read(9 * cs, &mut buf).unwrap();
        assert_eq!(buf, [0; 4]);
    }

    #[test]
    fn lookups_touch_only_the_unified_cache() {
        let (_n, chain, clock) = sq_chain(3);
        let mut d = driver(chain, clock);
        let mut buf = [0u8; 1];
        d.read(0, &mut buf).unwrap(); // deepest layer
        let s = d.counters();
        // all probes attributed to the active index; no per-file walk
        assert_eq!(s.per_file_lookups.iter().filter(|&&c| c > 0).count(), 1);
        assert_eq!(s.misses, 1, "one slice fetch from the active volume");
        assert_eq!(s.hit_unallocated, 1, "direct access to backing file");
    }

    #[test]
    fn one_miss_per_slice_regardless_of_owner() {
        let (_n, chain, clock) = sq_chain(4);
        let mut d = driver(chain, clock);
        let cs = 64 << 10;
        let mut buf = [0u8; 1];
        // clusters 0..4 are owned by 4 different layers but share a slice
        for i in 0..4u64 {
            d.read(i * cs, &mut buf).unwrap();
        }
        assert_eq!(d.counters().misses, 1);
        assert_eq!(d.counters().hit_unallocated, 4);
    }

    #[test]
    fn write_cows_and_future_reads_hit() {
        let (_n, chain, clock) = sq_chain(2);
        let mut d = driver(chain, clock);
        d.write(3, &[0xBB; 4]).unwrap();
        let mut buf = [0u8; 8];
        d.read(0, &mut buf).unwrap();
        assert_eq!(&buf[..3], &[1; 3]);
        assert_eq!(&buf[3..7], &[0xBB; 4]);
        let before = d.counters().hit_unallocated;
        d.read(0, &mut buf).unwrap();
        let after = d.counters();
        assert_eq!(after.hit_unallocated, before, "now owned by active");
        assert!(after.hits >= 1);
    }

    #[test]
    fn vanilla_chain_fallback_is_correct() {
        // build a vanilla (unstamped) chain, read through ScalableDriver
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            0,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 0..2 {
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[i as u8 + 1; 16]).unwrap();
            img.set_l2_entry(i as u64, L2Entry::local(off, None)).unwrap();
            snapshot::snapshot_vanilla(&mut chain, &node, &format!("img-{}", i + 1))
                .unwrap();
        }
        let mut d = driver(chain, clock);
        assert!(!d.complete_index);
        let cs = 64 << 10;
        let mut buf = [0u8; 4];
        d.read(0, &mut buf).unwrap();
        assert_eq!(buf, [1; 4]);
        d.read(cs, &mut buf).unwrap();
        assert_eq!(buf, [2; 4]);
        d.read(5 * cs, &mut buf).unwrap();
        assert_eq!(buf, [0; 4]);
    }

    #[test]
    fn flush_writes_corrections_back() {
        let (_n, chain, clock) = sq_chain(2);
        let mut d = driver(chain, clock);
        d.write(0, &[9; 4]).unwrap();
        d.flush().unwrap();
        let e = d.chain().active().l2_entry(0).unwrap();
        assert!(e.is_allocated_here());
        assert_eq!(e.bfi(), Some(d.chain().active().chain_index()));
    }

    /// Build a vanilla (unstamped) chain where files 0..n-1 each own one
    /// cluster and the active volume is empty, under a cost model where
    /// virtual time advances only in T_L units (t_ram = t_disk = 0): the
    /// clock then counts exactly device I/Os + chain hops.
    fn hop_cost_chain(n_layers: usize) -> (Chain, Arc<VirtClock>, CostModel) {
        let cost = CostModel {
            t_ram: 0,
            t_layers: 1_000,
            t_disk: 0,
            bandwidth: u64::MAX,
        };
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), cost);
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            0,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 0..n_layers {
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[i as u8 + 1; 16]).unwrap();
            img.set_l2_entry(i as u64, L2Entry::local(off, None)).unwrap();
            snapshot::snapshot_vanilla(&mut chain, &node, &format!("img-{}", i + 1))
                .unwrap();
        }
        (chain, clock, cost)
    }

    #[test]
    fn fallback_walk_charges_hops_like_vanilla() {
        // regression: the backward-compat chain walk never called
        // charge_hop(), so it was free in the cost model while
        // VanillaDriver pays one T_F per present-but-unallocated file —
        // reading the base cluster must cost the same through both
        use crate::vdisk::vanilla::VanillaDriver;
        let cache = CacheConfig::new(32, 1 << 20);
        let mut buf = [0u8; 4];

        let (chain_v, clock_v, cost_v) = hop_cost_chain(3);
        let mut dv = VanillaDriver::new(
            chain_v,
            cache,
            clock_v.clone(),
            cost_v,
            MemoryAccountant::new(),
        );
        let t0 = clock_v.now();
        dv.read(0, &mut buf).unwrap();
        let vanilla_ns = clock_v.now() - t0;
        assert_eq!(buf, [1; 4]);

        let (chain_s, clock_s, cost_s) = hop_cost_chain(3);
        let mut ds = ScalableDriver::new(
            chain_s,
            cache,
            clock_s.clone(),
            cost_s,
            MemoryAccountant::new(),
        );
        assert!(!ds.complete_index, "this is the compat path");
        let t0 = clock_s.now();
        ds.read(0, &mut buf).unwrap();
        let scalable_ns = clock_s.now() - t0;
        assert_eq!(buf, [1; 4]);

        // both walks: 3 slice fetches + 1 data read + 2 chain hops
        assert_eq!(
            scalable_ns, vanilla_ns,
            "the compat walk must pay the same T_F hops as VanillaDriver"
        );
        assert!(vanilla_ns >= 6 * cost_v.t_layers, "hops are in the bill");
    }

    #[test]
    fn reopen_persists_corrected_slices() {
        // regression: reopen() rebuilt the unified cache without draining
        // it, silently discarding dirty corrected slices due for
        // writeback
        let (chain, clock, _cost) = hop_cost_chain(2);
        let mut d = driver(chain, clock);
        let mut buf = [0u8; 4];
        d.read(0, &mut buf).unwrap(); // correction now dirty in the cache
        assert_eq!(buf, [1; 4]);
        let before = d.chain().active().l2_entry(0).unwrap();
        assert!(before.is_zero(), "correction not yet written back");
        d.reopen().unwrap();
        let e = d.chain().active().l2_entry(0).unwrap();
        assert_eq!(e.bfi(), Some(0), "corrected stamp persisted by reopen");
        assert!(!e.is_allocated_here(), "stamp, not a bogus local claim");
        // and the chain still reads correctly through a fresh cache
        d.read(0, &mut buf).unwrap();
        assert_eq!(buf, [1; 4]);
    }
}
