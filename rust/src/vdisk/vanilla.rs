//! The vanilla (vQemu) driver: §2's recursive, per-backing-file design.
//!
//! Resolution walks the chain from the active volume downwards. Each file
//! has its own independently managed L2 slice cache; a lookup probes the
//! file's cache (hit / hit-unallocated), fetching the slice from that
//! file on a miss — Fig 3's "journey of an IO request", faithfully. Cost:
//! O(chain length) cache probes (and potentially fetches) per request,
//! and per-file cache memory — the two §4 scalability problems.

use super::common::{resolve_grouped, DriverBase, VSeg};
use super::{Driver, DriverKind, VecIoSnapshot};
use crate::cache::{CacheConfig, SliceCache};
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::histogram::Histogram;
use crate::metrics::memory::MemoryAccountant;
use crate::qcow::entry::L2Entry;
use crate::qcow::Chain;
use anyhow::Result;
use std::sync::Arc;

pub struct VanillaDriver {
    base: DriverBase,
    /// One cache per file, index-aligned with the chain ("one cache for
    /// the active volume and one cache per backing file", §2).
    caches: Vec<SliceCache>,
    per_file_cache: CacheConfig,
}

impl VanillaDriver {
    pub fn new(
        chain: Chain,
        per_file_cache: CacheConfig,
        clock: Arc<VirtClock>,
        cost: CostModel,
        acct: Arc<MemoryAccountant>,
    ) -> Self {
        let caches = chain
            .images()
            .iter()
            .map(|_| SliceCache::new(per_file_cache, &acct))
            .collect();
        VanillaDriver {
            base: DriverBase::new(chain, clock, cost, acct),
            caches,
            per_file_cache,
        }
    }

    /// Resolve one virtual cluster by walking the chain (Fig 3).
    fn resolve(&mut self, vcluster: u64) -> Result<Option<(u16, u64)>> {
        let n = self.base.chain.len();
        let cfg = *self.caches[0].cfg();
        let key = cfg.slice_key(vcluster);
        let idx_in_slice = cfg.slice_index(vcluster) as usize;
        for idx in (0..n).rev() {
            self.base.counters.lookup_on(idx);
            self.base.charge_ram();
            // 1) probe this file's cache
            if let Some(slice) = self.caches[idx].get(key) {
                let e = L2Entry(slice.entries[idx_in_slice]);
                match e.vanilla_view() {
                    Some(off) => {
                        self.base.counters.hit();
                        return Ok(Some((idx as u16, off)));
                    }
                    None => {
                        // "cache hit unallocated" -> move to the next
                        // file: one Eq. 1 hop (T_F) of driver call chain
                        self.base.counters.unallocated();
                        self.base.charge_hop();
                        continue;
                    }
                }
            }
            // 2) slice not cached: try to fetch it from this file
            let img = &self.base.chain.images()[idx];
            let (l1_idx, _) = img.geom().split_vcluster(vcluster);
            let l2_off = img.l1_entry(l1_idx);
            if l2_off == 0 {
                // no L2 table at all in this file: nothing to fetch,
                // move down the chain (in-RAM L1 check only)
                continue;
            }
            // device fetch of the slice ("brought into the cache", §2)
            let slice_start = cfg.slice_base(key) % img.geom().entries_per_l2();
            let entries = img.read_l2_slice(l2_off, slice_start, cfg.slice_entries)?;
            self.base.counters.miss();
            if let Some((ek, evicted)) = self.caches[idx].insert(key, entries) {
                // only the active volume's cache can hold dirty slices
                if evicted.dirty && idx == n - 1 {
                    self.writeback(idx, ek, &evicted.entries)?;
                }
            }
            // 3) re-examine the (now cached) entry — Fig 3 steps 5-6
            self.base.charge_ram();
            let slice = self.caches[idx].get(key).expect("just inserted");
            let e = L2Entry(slice.entries[idx_in_slice]);
            match e.vanilla_view() {
                Some(off) => {
                    self.base.counters.hit();
                    return Ok(Some((idx as u16, off)));
                }
                None => {
                    self.base.counters.unallocated();
                    self.base.charge_hop();
                }
            }
        }
        Ok(None)
    }

    /// Batched Fig 3 walk for one slice group: probe each file's cache
    /// ONCE per level for the whole group instead of once per cluster
    /// (fetching the file's slice on a miss as usual); clusters drop out
    /// of the pending set as the walk descends. One T_M charge and one
    /// chain hop per level per group — the vanilla design still walks
    /// the chain, but a batch pays the walk once.
    fn resolve_group(
        &mut self,
        group: &[VSeg],
        key: u64,
        out: &mut Vec<Option<(u16, u64)>>,
    ) -> Result<()> {
        let n = self.base.chain.len();
        let cfg = *self.caches[0].cfg();
        let t0 = self.base.clock.now();
        let mut results: Vec<Option<(u16, u64)>> = vec![None; group.len()];
        let mut pending: Vec<usize> = (0..group.len()).collect();
        for idx in (0..n).rev() {
            if pending.is_empty() {
                break;
            }
            self.base.counters.lookup_on(idx);
            self.base.charge_ram();
            if self.caches[idx].get(key).is_none() {
                // slice not cached: try to fetch it from this file
                let img = &self.base.chain.images()[idx];
                let (l1_idx, _) = img.geom().split_vcluster(group[0].vc);
                let l2_off = img.l1_entry(l1_idx);
                if l2_off == 0 {
                    // no L2 table at all in this file: nothing to fetch,
                    // move down the chain (in-RAM L1 check only)
                    continue;
                }
                let slice_start = cfg.slice_base(key) % img.geom().entries_per_l2();
                let entries = img.read_l2_slice(l2_off, slice_start, cfg.slice_entries)?;
                self.base.counters.miss();
                if let Some((ek, evicted)) = self.caches[idx].insert(key, entries) {
                    // only the active volume's cache can hold dirty slices
                    if evicted.dirty && idx == n - 1 {
                        self.writeback(idx, ek, &evicted.entries)?;
                    }
                }
                self.base.charge_ram(); // re-examination (Fig 3 steps 5-6)
            }
            let before = pending.len();
            {
                let slice = self.caches[idx].get(key).expect("resident");
                pending.retain(|&g| {
                    let e =
                        L2Entry(slice.entries[cfg.slice_index(group[g].vc) as usize]);
                    match e.vanilla_view() {
                        Some(off) => {
                            results[g] = Some((idx as u16, off));
                            false
                        }
                        None => true,
                    }
                });
            }
            self.base.counters.add_hits((before - pending.len()) as u64);
            if !pending.is_empty() {
                // "cache hit unallocated" for the rest: one amortized
                // Eq. 1 hop (T_F) down to the next file for the group
                self.base.counters.add_unallocated(pending.len() as u64);
                self.base.charge_hop();
            }
        }
        let dt = self.base.clock.now() - t0;
        self.base.record_lookup(dt);
        out.extend(results);
        Ok(())
    }

    fn writeback(&self, idx: usize, key: u64, entries: &[u64]) -> Result<()> {
        let img = &self.base.chain.images()[idx];
        let cfg = self.caches[idx].cfg();
        let vc = cfg.slice_base(key);
        let (l1_idx, _) = img.geom().split_vcluster(vc);
        let l2_off = img.ensure_l2(l1_idx)?;
        let slice_start = cfg.slice_base(key) % img.geom().entries_per_l2();
        img.write_l2_slice(l2_off, slice_start, entries)
    }

    /// Update the active volume's cached slice after a write (the on-disk
    /// entry is updated write-through by `cow_write`).
    fn update_cache_after_write(&mut self, vcluster: u64, new_off: u64) {
        let active = self.base.chain.active();
        let stamp = if active.has_bfi() {
            Some(active.chain_index())
        } else {
            None
        };
        self.update_cache_entry(vcluster, L2Entry::local(new_off, stamp).raw());
    }

    /// Mirror an already-persisted raw L2 entry into the active volume's
    /// cached slice (capacity-policy writes may leave zero / compressed /
    /// remote-share entries, not just plain local ones).
    fn update_cache_entry(&mut self, vcluster: u64, raw: u64) {
        let n = self.base.chain.len();
        let cfg = *self.caches[n - 1].cfg();
        let key = cfg.slice_key(vcluster);
        let idx_in_slice = cfg.slice_index(vcluster) as usize;
        if let Some(slice) = self.caches[n - 1].get(key) {
            slice.entries[idx_in_slice] = raw;
            // entry already persisted write-through; keep slice clean
        }
    }
}

impl Driver for VanillaDriver {
    fn read(&mut self, voff: u64, buf: &mut [u8]) -> Result<()> {
        let mut cursor = 0usize;
        for (vc, within, len) in self.base.segments(voff, buf.len()) {
            let (resolved, dt) = {
                let t0 = self.base.clock.now();
                let r = self.resolve(vc)?;
                (r, self.base.clock.now() - t0)
            };
            self.base.record_lookup(dt);
            self.base
                .read_segment(resolved, within, &mut buf[cursor..cursor + len])?;
            cursor += len;
        }
        Ok(())
    }

    /// Vectored read: the batched chain walk resolves each slice group
    /// with one probe per file level, then the contiguity coalescer
    /// serves physically adjacent clusters with one device read per run.
    fn readv(&mut self, iovs: &mut [(u64, &mut [u8])]) -> Result<()> {
        let segs = self.base.vsegments(iovs);
        let slice_entries = self.caches[0].cfg().slice_entries;
        let resolved = resolve_grouped(&segs, slice_entries, |g, k, out| {
            self.resolve_group(g, k, out)
        })?;
        self.base.read_resolved(&segs, &resolved, iovs)
    }

    fn write(&mut self, voff: u64, data: &[u8]) -> Result<()> {
        let mut cursor = 0usize;
        let active_idx = (self.base.chain.len() - 1) as u16;
        let cs = self.base.chain.active().geom().cluster_size();
        for (vc, within, len) in self.base.segments(voff, data.len()) {
            let (mut resolved, dt) = {
                let t0 = self.base.clock.now();
                let r = self.resolve(vc)?;
                (r, self.base.clock.now() - t0)
            };
            self.base.record_lookup(dt);
            // write intercept (live block jobs): mark this cluster as
            // newer than the job; if the job already copied it into the
            // active volume, the cached mapping may be stale — use the
            // on-disk entry. If a stale writeback clobbered that entry,
            // re-link to the job's copy rather than trusting it (a zero
            // entry would make cow_write zero-fill and lose data).
            self.base.fence.note_guest_write(vc);
            let job_moved = self.base.fence.job_moved(vc);
            if let Some(moved_off) = job_moved {
                let active = self.base.chain.active();
                resolved = match active.l2_entry(vc)?.vanilla_view() {
                    Some(off) => Some((active_idx, off)),
                    None => {
                        let stamp = if active.has_bfi() {
                            Some(active_idx)
                        } else {
                            None
                        };
                        active.set_l2_entry(vc, L2Entry::local(moved_off, stamp))?;
                        Some((active_idx, moved_off))
                    }
                };
            }
            let chunk = &data[cursor..cursor + len];
            if within == 0 && len as u64 == cs && self.base.policy.any_enabled() {
                // full-cluster write through the capacity policy (zero
                // detection / dedup / compression, plain fallback)
                let out = self.base.full_cluster_write(vc, resolved, chunk, false)?;
                self.update_cache_entry(vc, out.entry.raw());
                cursor += len;
                continue;
            }
            match resolved {
                Some((bfi, off))
                    if bfi == active_idx && self.base.can_write_in_place(off)? =>
                {
                    // in-place write to the active volume
                    self.base.note_inplace_write(off);
                    self.base.chain.active().write_data(off, within, chunk)?;
                    if job_moved.is_some() {
                        // resync the cached entry with the on-disk one
                        self.update_cache_after_write(vc, off);
                    } else {
                        let key = self.caches[0].cfg().slice_key(vc);
                        self.caches[active_idx as usize].mark_dirty(key);
                    }
                }
                other => {
                    let new_off = self.base.cow_write(vc, other, within, chunk)?;
                    self.update_cache_after_write(vc, new_off);
                }
            }
            cursor += len;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        let n = self.base.chain.len();
        for idx in 0..n {
            let dirty = self.caches[idx].drain();
            for (key, slice) in dirty {
                self.writeback(idx, key, &slice.entries)?;
            }
        }
        // durability barrier: flush acknowledges the guest's FLUSH — all
        // data and metadata written so far must survive a crash
        self.base.chain.active().flush()
    }

    fn kind(&self) -> DriverKind {
        DriverKind::Vanilla
    }

    fn chain(&self) -> &Chain {
        &self.base.chain
    }

    fn chain_mut(&mut self) -> &mut Chain {
        &mut self.base.chain
    }

    fn reopen(&mut self) -> Result<()> {
        // one fresh cache per (possibly different) file; per-snapshot
        // memory re-registered for the new shape
        self.caches = self
            .base
            .chain
            .images()
            .iter()
            .map(|_| SliceCache::new(self.per_file_cache, &self.base.acct))
            .collect();
        self.base.refresh_mem();
        Ok(())
    }

    fn fence(&self) -> &Arc<crate::blockjob::JobFence> {
        &self.base.fence
    }

    fn counters(&self) -> CounterSnapshot {
        self.base.counters.snapshot()
    }

    fn lookup_latency(&self) -> Histogram {
        self.base.lookup_latency()
    }

    fn vec_io(&self) -> VecIoSnapshot {
        VecIoSnapshot {
            merged_ios: self.base.merged_ios,
            coalesced_bytes: self.base.coalesced_bytes,
        }
    }

    fn cache_bytes(&self) -> u64 {
        self.caches.iter().map(|c| c.resident_bytes()).sum()
    }

    fn set_capacity_policy(&mut self, policy: crate::dedup::CapacityPolicy) {
        self.base.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qcow::image::{DataMode, Image};
    use crate::qcow::layout::Geometry;
    use crate::qcow::snapshot;
    use crate::storage::node::StorageNode;

    fn chain_with_layers(n_snapshots: usize) -> (Arc<StorageNode>, Chain, Arc<VirtClock>) {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            0,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 0..n_snapshots {
            // write one distinct cluster per layer before snapshotting
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[i as u8 + 1; 32]).unwrap();
            img.set_l2_entry(i as u64, L2Entry::local(off, None)).unwrap();
            snapshot::snapshot_vanilla(&mut chain, &node, &format!("img-{}", i + 1))
                .unwrap();
        }
        (node, chain, clock)
    }

    fn driver(chain: Chain, clock: Arc<VirtClock>) -> VanillaDriver {
        VanillaDriver::new(
            chain,
            CacheConfig::new(32, 1 << 20),
            clock,
            CostModel::default(),
            MemoryAccountant::new(),
        )
    }

    #[test]
    fn reads_layers_through_chain() {
        let (_n, chain, clock) = chain_with_layers(3);
        let mut d = driver(chain, clock);
        let cs = 64 << 10;
        let mut buf = [0u8; 4];
        for i in 0..3u64 {
            d.read(i * cs, &mut buf).unwrap();
            assert_eq!(buf, [i as u8 + 1; 4], "layer {i}");
        }
        // unallocated cluster reads zeros
        d.read(10 * cs, &mut buf).unwrap();
        assert_eq!(buf, [0; 4]);
    }

    #[test]
    fn chain_walk_costs_grow_with_depth() {
        let (_n, chain, clock) = chain_with_layers(3);
        let mut d = driver(chain, clock);
        let mut buf = [0u8; 1];
        d.read(0, &mut buf).unwrap(); // cluster 0 lives at the base
        let s = d.counters();
        // walked all 4 files: probes attributed to every index
        assert_eq!(s.per_file_lookups.len(), 4);
        assert!(s.hit_unallocated >= 1 || s.misses >= 1);
        assert_eq!(s.hits, 1);
    }

    #[test]
    fn write_cows_into_active_volume() {
        let (_n, chain, clock) = chain_with_layers(2);
        let mut d = driver(chain, clock);
        d.write(5, &[0xEE; 3]).unwrap();
        let mut buf = [0u8; 8];
        d.read(0, &mut buf).unwrap();
        // first 5 bytes preserved from the base layer, then the write
        assert_eq!(&buf[..5], &[1; 5]);
        assert_eq!(&buf[5..8], &[0xEE; 3]);
        // the active volume owns the cluster now
        let (bfi, _) = d.chain().resolve_walk(0).unwrap().unwrap();
        assert_eq!(bfi as usize, d.chain().len() - 1);
        // backing file content untouched (COW invariant)
        let (b0, off0) = (0u16, d.chain().get(0).unwrap().l2_entry(0).unwrap().host_offset());
        let mut orig = [0u8; 8];
        d.chain().get(b0).unwrap().read_data(off0, 0, &mut orig).unwrap();
        assert_eq!(orig, [1; 8]);
    }

    #[test]
    fn second_read_hits_cache() {
        let (_n, chain, clock) = chain_with_layers(1);
        let mut d = driver(chain, clock);
        let mut buf = [0u8; 1];
        d.read(0, &mut buf).unwrap();
        let m1 = d.counters().misses;
        d.read(1, &mut buf).unwrap(); // same slice
        assert_eq!(d.counters().misses, m1, "no new miss within the slice");
    }

    #[test]
    fn flush_persists_dirty_slices() {
        let (_n, chain, clock) = chain_with_layers(1);
        let mut d = driver(chain, clock);
        d.write(0, &[7; 16]).unwrap();
        d.flush().unwrap();
        // reopen-style check via uncached entry read
        let e = d.chain().active().l2_entry(0).unwrap();
        assert!(e.is_allocated_here());
    }
}
