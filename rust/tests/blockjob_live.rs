//! Live block-job invariants (DESIGN.md §7): a live-stream job
//! interleaved with random guest writes converges to a chain whose
//! guest reads are bit-identical to the offline `stream_merge` result;
//! live stamp migrates a running vanilla chain to the SQEMU format; the
//! coordinator serves guest I/O throughout (no pause), admits jobs
//! under per-node budgets, and every completed job leaves a clean
//! `CheckReport`.

use sqemu::blockjob::{JobKind, JobRunner, JobShared, JobState, LiveStreamJob, Step};
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, JobSpec, VmConfig};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::{qcheck, snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::util::prop::forall;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::{Driver, DriverKind};
use std::sync::Arc;
use std::time::{Duration, Instant};

const CS: u64 = 64 << 10;

fn prop_spec(seed: u64) -> ChainSpec {
    ChainSpec {
        disk_size: 64 * CS, // 64 virtual clusters
        chain_len: 6,
        populated: 0.5,
        stamped: true,
        data_mode: DataMode::Real,
        prefix: "p".into(),
        seed,
        ..Default::default()
    }
}

fn driver_for(chain: Chain, clock: Arc<VirtClock>) -> ScalableDriver {
    ScalableDriver::new(
        chain,
        CacheConfig::new(16, 128 << 10),
        clock,
        CostModel::default(),
        MemoryAccountant::new(),
    )
}

/// The tentpole property: live stream + concurrent random guest writes
/// ≡ offline merge of the same chain with the same writes applied.
#[test]
fn live_stream_with_guest_writes_matches_offline_merge_bit_for_bit() {
    forall(0x11FE, 5, |rng| {
        let spec = prop_spec(0x5EED ^ rng.below(1 << 20));
        let clock_a = VirtClock::new();
        let node_a = StorageNode::new("a", clock_a.clone(), CostModel::default());
        let clock_b = VirtClock::new();
        let node_b = StorageNode::new("b", clock_b.clone(), CostModel::default());
        // two bit-identical chains (generation is deterministic)
        let chain_a = generate(&*node_a, &spec).unwrap();
        let chain_b = generate(&*node_b, &spec).unwrap();
        let len = chain_a.len();
        let mut da = driver_for(chain_a, clock_a.clone());
        let mut db = driver_for(chain_b, clock_b.clone());

        // live job on A, interleaved with guest writes applied to BOTH
        let fence = Arc::clone(da.fence());
        let rate = if rng.chance(0.5) { 0 } else { 2 << 20 };
        let shared = Arc::new(JobShared::new("prop", JobKind::Stream, rate));
        let job = Box::new(LiveStreamJob::new(da.chain(), Arc::clone(&fence)));
        let mut runner =
            JobRunner::new(job, Arc::clone(&shared), fence, 8, 8 * CS, clock_a.now());
        let mut finished = false;
        let mut guard = 0u32;
        while !finished {
            guard += 1;
            assert!(guard < 100_000, "job never converged");
            // a burst of guest traffic against the live VM
            for _ in 0..rng.below(4) {
                let vc = rng.below(64);
                let within = rng.below(CS - 64);
                let mut data = vec![0u8; 1 + rng.below(63) as usize];
                rng.fill_bytes(&mut data);
                da.write(vc * CS + within, &data).unwrap();
                db.write(vc * CS + within, &data).unwrap();
                if rng.chance(0.3) {
                    let mut back = vec![0u8; data.len()];
                    da.read(vc * CS + within, &mut back).unwrap();
                    assert_eq!(back, data, "read-your-write during job");
                }
            }
            match runner.step(&mut da, clock_a.now()) {
                Step::Finished => finished = true,
                Step::Starved { ready_at } => {
                    let now = clock_a.now();
                    clock_a.advance(ready_at - now);
                }
                _ => {}
            }
        }
        let st = shared.status();
        assert_eq!(st.state, JobState::Completed, "error: {:?}", st.error);
        assert_eq!(da.chain().len(), 1, "live chain collapsed");

        // offline baseline on B: full stop-the-world merge
        db.flush().unwrap();
        snapshot::stream_merge(db.chain_mut(), 0, (len - 1) as u16).unwrap();
        db.reopen().unwrap();

        // guest view must agree bit-for-bit across the whole disk
        let mut buf_a = vec![0u8; CS as usize];
        let mut buf_b = vec![0u8; CS as usize];
        for vc in 0..64u64 {
            da.read(vc * CS, &mut buf_a).unwrap();
            db.read(vc * CS, &mut buf_b).unwrap();
            assert_eq!(buf_a, buf_b, "vc={vc} diverged from offline merge");
        }
        da.flush().unwrap();
        let ra = qcheck::check_chain(da.chain()).unwrap();
        assert!(ra.is_clean(), "{:?}", ra.errors);
        let rb = qcheck::check_chain(db.chain()).unwrap();
        assert!(rb.is_clean(), "{:?}", rb.errors);
    });
}

/// Same property as above, but the concurrent guest writes arrive as
/// vectored batches (`writev`) and the mid-job probes as `readv`:
/// batching must not change what the live job sees or produces.
#[test]
fn live_stream_with_batched_guest_writes_matches_offline_merge() {
    forall(0x11FF, 3, |rng| {
        let spec = prop_spec(0xB5EED ^ rng.below(1 << 20));
        let clock_a = VirtClock::new();
        let node_a = StorageNode::new("a", clock_a.clone(), CostModel::default());
        let clock_b = VirtClock::new();
        let node_b = StorageNode::new("b", clock_b.clone(), CostModel::default());
        let chain_a = generate(&*node_a, &spec).unwrap();
        let chain_b = generate(&*node_b, &spec).unwrap();
        let len = chain_a.len();
        let mut da = driver_for(chain_a, clock_a.clone());
        let mut db = driver_for(chain_b, clock_b.clone());

        let fence = Arc::clone(da.fence());
        let rate = if rng.chance(0.5) { 0 } else { 2 << 20 };
        let shared = Arc::new(JobShared::new("propv", JobKind::Stream, rate));
        let job = Box::new(LiveStreamJob::new(da.chain(), Arc::clone(&fence)));
        let mut runner =
            JobRunner::new(job, Arc::clone(&shared), fence, 8, 8 * CS, clock_a.now());
        let mut finished = false;
        let mut guard = 0u32;
        while !finished {
            guard += 1;
            assert!(guard < 100_000, "job never converged");
            // one batched burst of guest writes, applied to BOTH sides
            let n = rng.below(4) as usize;
            let batch: Vec<(u64, Vec<u8>)> = (0..n)
                .map(|_| {
                    let vc = rng.below(64);
                    let within = rng.below(CS - 64);
                    let mut data = vec![0u8; 1 + rng.below(63) as usize];
                    rng.fill_bytes(&mut data);
                    (vc * CS + within, data)
                })
                .collect();
            {
                let iovs: Vec<(u64, &[u8])> =
                    batch.iter().map(|(v, d)| (*v, d.as_slice())).collect();
                da.writev(&iovs).unwrap();
            }
            for (v, d) in &batch {
                db.write(*v, d.clone()).unwrap();
            }
            if rng.chance(0.3) {
                // mid-job vectored probes: the live side must read the
                // same bytes as the untouched side at all times
                let reqs: Vec<(u64, usize)> = (0..4)
                    .map(|_| (rng.below(64 * CS - 128), 64usize))
                    .collect();
                let mut ba: Vec<Vec<u8>> = reqs.iter().map(|r| vec![0u8; r.1]).collect();
                let mut bb: Vec<Vec<u8>> = reqs.iter().map(|r| vec![0u8; r.1]).collect();
                {
                    let mut iovs: Vec<(u64, &mut [u8])> = reqs
                        .iter()
                        .zip(ba.iter_mut())
                        .map(|(r, b)| (r.0, b.as_mut_slice()))
                        .collect();
                    da.readv(&mut iovs).unwrap();
                }
                {
                    let mut iovs: Vec<(u64, &mut [u8])> = reqs
                        .iter()
                        .zip(bb.iter_mut())
                        .map(|(r, b)| (r.0, b.as_mut_slice()))
                        .collect();
                    db.readv(&mut iovs).unwrap();
                }
                assert_eq!(ba, bb, "mid-job vectored read diverged");
            }
            match runner.step(&mut da, clock_a.now()) {
                Step::Finished => finished = true,
                Step::Starved { ready_at } => {
                    let now = clock_a.now();
                    clock_a.advance(ready_at - now);
                }
                _ => {}
            }
        }
        let st = shared.status();
        assert_eq!(st.state, JobState::Completed, "error: {:?}", st.error);
        assert_eq!(da.chain().len(), 1, "live chain collapsed");

        db.flush().unwrap();
        snapshot::stream_merge(db.chain_mut(), 0, (len - 1) as u16).unwrap();
        db.reopen().unwrap();

        // post-merge: whole-disk vectored comparison, 8 clusters a batch
        for base in (0..64u64).step_by(8) {
            let reqs: Vec<(u64, usize)> =
                (0..8).map(|i| ((base + i) * CS, CS as usize)).collect();
            let mut ba: Vec<Vec<u8>> = reqs.iter().map(|r| vec![0u8; r.1]).collect();
            let mut bb: Vec<Vec<u8>> = reqs.iter().map(|r| vec![0u8; r.1]).collect();
            {
                let mut iovs: Vec<(u64, &mut [u8])> = reqs
                    .iter()
                    .zip(ba.iter_mut())
                    .map(|(r, b)| (r.0, b.as_mut_slice()))
                    .collect();
                da.readv(&mut iovs).unwrap();
            }
            {
                let mut iovs: Vec<(u64, &mut [u8])> = reqs
                    .iter()
                    .zip(bb.iter_mut())
                    .map(|(r, b)| (r.0, b.as_mut_slice()))
                    .collect();
                db.readv(&mut iovs).unwrap();
            }
            assert_eq!(ba, bb, "base={base} diverged from offline merge");
        }
        da.flush().unwrap();
        let ra = qcheck::check_chain(da.chain()).unwrap();
        assert!(ra.is_clean(), "{:?}", ra.errors);
        let rb = qcheck::check_chain(db.chain()).unwrap();
        assert!(rb.is_clean(), "{:?}", rb.errors);
    });
}

fn vm_cfg(kind: DriverKind, chain_len: usize, prefix: &str, stamped: bool) -> VmConfig {
    VmConfig {
        driver: kind,
        cache: CacheConfig::new(64, 256 << 10),
        chain: VmChain::Generate(ChainSpec {
            disk_size: 16 << 20,
            chain_len,
            populated: 0.3,
            stamped,
            data_mode: DataMode::Real,
            prefix: prefix.into(),
            ..Default::default()
        }),
    }
}

fn wait_terminal(shared: &Arc<sqemu::blockjob::JobShared>) -> JobState {
    let t0 = Instant::now();
    loop {
        let s = shared.state();
        if s.is_terminal() {
            return s;
        }
        assert!(t0.elapsed() < Duration::from_secs(60), "job stuck: {s:?}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Acceptance: a live-stream job on a length-100 chain completes while
/// the VM keeps serving reads and writes, the result passes qcheck, and
/// content is preserved.
#[test]
fn live_stream_on_hundred_deep_chain_while_serving() {
    let coord = Coordinator::with_fresh_nodes(2).unwrap();
    let c = coord
        .launch_vm("vm", vm_cfg(DriverKind::Scalable, 100, "e", true))
        .unwrap();
    // pre-job content probes
    let offsets: Vec<u64> = (0..24).map(|i| i * 650_000).collect();
    let before: Vec<Vec<u8>> = offsets.iter().map(|&o| c.read(o, 64).unwrap()).collect();

    // start paused: deterministically prove the VM serves guest I/O
    // while an incomplete job is pending (no stop-the-world pause)
    let h = coord
        .start_job("vm", JobSpec::stream(256 << 20).paused())
        .unwrap();
    let mut served = 0u64;
    for (i, &o) in offsets.iter().enumerate() {
        assert_eq!(c.read(o, 64).unwrap(), before[i], "read blocked by pending job");
        served += 1;
    }
    c.write(1 << 20, vec![0xC4; 128]).unwrap();
    served += 1;
    assert!(!h.state().is_terminal(), "paused job cannot have finished");
    coord.resume_job(&h.id).unwrap();
    // keep serving while the job drains the 100-deep chain
    while !h.state().is_terminal() {
        for (i, &o) in offsets.iter().enumerate() {
            assert_eq!(c.read(o, 64).unwrap(), before[i], "content changed mid-job");
        }
        served += offsets.len() as u64;
    }
    assert_eq!(wait_terminal(&h), JobState::Completed, "err: {:?}", h.status().error);
    assert!(served > 0, "no guest requests overlapped the job");
    // post-job: same content, short chain, clean check, stats recorded
    for (i, &o) in offsets.iter().enumerate() {
        assert_eq!(c.read(o, 64).unwrap(), before[i], "content lost by job");
    }
    let stats = coord.vm_stats("vm").unwrap();
    assert_eq!(stats.jobs_started, 1);
    assert_eq!(stats.jobs_completed, 1);
    assert!(stats.job_copied_clusters > 0);
    assert!(stats.req_count > 0 && stats.req_p99_ns > 0, "latency tracked");
    let st = h.status();
    assert_eq!(st.processed, st.total);
    assert!(st.increments > 1, "work was incremental, not one pause");

    coord.stop_vm("vm").unwrap();
    let chain = Chain::open(coord.nodes.as_ref(), "e-99", DataMode::Real).unwrap();
    assert_eq!(chain.len(), 1, "chain collapsed to the active volume");
    assert!(qcheck::check_chain(&chain).unwrap().is_clean());
}

/// Live stamp migrates a running vanilla chain to the SQEMU format.
#[test]
fn live_stamp_converts_running_vanilla_chain() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    let c = coord
        .launch_vm("vm", vm_cfg(DriverKind::Scalable, 20, "s", false))
        .unwrap();
    let h = coord.start_job("vm", JobSpec::stamp(0)).unwrap();
    // one concurrent write lands regardless of how fast the job runs
    c.write(2 << 20, vec![9u8; 64]).unwrap();
    while !h.state().is_terminal() {
        let _ = c.read(5 << 20, 64).unwrap();
    }
    assert_eq!(wait_terminal(&h), JobState::Completed, "err: {:?}", h.status().error);
    assert_eq!(c.read(2 << 20, 64).unwrap(), vec![9u8; 64]);
    coord.stop_vm("vm").unwrap();

    let chain = Chain::open(coord.nodes.as_ref(), "s-19", DataMode::Real).unwrap();
    assert_eq!(chain.len(), 20, "stamping does not shorten the chain");
    let active = chain.active();
    assert!(active.has_bfi(), "format flag flipped live");
    let own = active.chain_index();
    for vc in 0..active.geom().num_vclusters() {
        assert_eq!(
            active.l2_entry(vc).unwrap().sqemu_view(own),
            chain.resolve_walk(vc).unwrap(),
            "stamp disagrees with walk at vc={vc}"
        );
    }
    assert!(qcheck::check_chain(&chain).unwrap().is_clean());
}

/// Job lifecycle: paused jobs hold their reservation and block
/// conflicting chain operations; cancel is cooperative; the scheduler
/// rejects jobs past the per-node budget and releases on completion.
#[test]
fn job_lifecycle_admission_and_cancel() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    coord
        .launch_vm("vm", vm_cfg(DriverKind::Scalable, 6, "l", true))
        .unwrap();
    let h = coord
        .start_job("vm", JobSpec::stream(1 << 20).paused())
        .unwrap();
    assert_eq!(h.state(), JobState::Paused);
    // conflicting chain ops are refused while a job exists
    assert!(coord.snapshot_vm("vm", "l-snap").is_err());
    // only one job per VM
    assert!(coord.start_job("vm", JobSpec::stream(1 << 20)).is_err());
    // cooperative cancel from the control plane
    coord.cancel_job(&h.id).unwrap();
    assert_eq!(wait_terminal(&h), JobState::Cancelled);
    let stats = coord.vm_stats("vm").unwrap();
    assert_eq!(stats.jobs_cancelled, 1);
    // reservation released: a new job is admitted and chain ops resume
    let h2 = coord.start_job("vm", JobSpec::stream(0)).unwrap();
    assert_eq!(wait_terminal(&h2), JobState::Completed, "err: {:?}", h2.status().error);
    coord.snapshot_vm("vm", "l-snap").unwrap();
    assert_eq!(coord.list_jobs().len(), 2);
    coord.shutdown();
}

/// A vanilla-driver VM can also be streamed live (the intercept rides
/// the vanilla write path too).
#[test]
fn live_stream_under_vanilla_driver() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    let c = coord
        .launch_vm("vm", vm_cfg(DriverKind::Vanilla, 12, "v", false))
        .unwrap();
    let before = c.read(3 << 20, 64).unwrap();
    let h = coord.start_job("vm", JobSpec::stream(0)).unwrap();
    c.write(7 << 20, vec![3u8; 32]).unwrap();
    while !h.state().is_terminal() {
        let _ = c.read(3 << 20, 64).unwrap();
    }
    assert_eq!(wait_terminal(&h), JobState::Completed, "err: {:?}", h.status().error);
    assert_eq!(c.read(3 << 20, 64).unwrap(), before);
    assert_eq!(c.read(7 << 20, 32).unwrap(), vec![3u8; 32]);
    coord.stop_vm("vm").unwrap();
    let chain = Chain::open(coord.nodes.as_ref(), "v-11", DataMode::Real).unwrap();
    assert_eq!(chain.len(), 1);
    assert!(qcheck::check_chain(&chain).unwrap().is_clean());
}
