//! Capacity-subsystem invariants (DESIGN.md §13):
//!
//! * with the FULL policy on (zero detection + compression + dedup),
//!   reads equal writes bit-for-bit on both drivers, across zero,
//!   compressed, dedup-shared and plain clusters, under aligned and
//!   unaligned traffic, against a byte-level shadow disk;
//! * a dedup-shared extent is never reclaimed while any referent
//!   remains — refcounts gate reclaim, and releasing the last referent
//!   frees the cluster instead of leaking it;
//! * rewrites of golden-base content resolve to remote references into
//!   the seeded base extent and allocate nothing in the active volume.

use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::dedup::{seed_chain, CapacityPolicy, DedupIndex};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::qcheck;
use sqemu::qcow::Chain;
use sqemu::storage::node::StorageNode;
use sqemu::util::prop::forall;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::Driver;
use std::sync::Arc;

const CS: u64 = 64 << 10;
const CLUSTERS: u64 = 64;
const DISK: u64 = CLUSTERS * CS;

fn chain_on(
    node_name: &str,
    stamped: bool,
    seed: u64,
    populated: f64,
    chain_len: usize,
    clock: &Arc<VirtClock>,
) -> Chain {
    let node = StorageNode::new(node_name, Arc::clone(clock), CostModel::default());
    generate(
        &*node,
        &ChainSpec {
            disk_size: DISK,
            chain_len,
            populated,
            stamped,
            data_mode: DataMode::Real,
            prefix: "c".into(),
            seed,
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn reads_equal_writes_bit_for_bit_under_full_policy() {
    forall(0xCA9A_11, 5, |rng| {
        let seed = rng.below(1 << 20);
        for stamped in [true, false] {
            let clock = VirtClock::new();
            let chain = chain_on("rt", stamped, seed, 0.4, 3, &clock);
            let cfg = CacheConfig::new(16, 128 << 10);
            let ix = Arc::new(DedupIndex::new());
            let mut d: Box<dyn Driver> = if stamped {
                Box::new(ScalableDriver::new(
                    chain,
                    cfg,
                    clock,
                    CostModel::default(),
                    MemoryAccountant::new(),
                ))
            } else {
                Box::new(VanillaDriver::new(
                    chain,
                    cfg,
                    clock,
                    CostModel::default(),
                    MemoryAccountant::new(),
                ))
            };
            seed_chain(&ix, "n", d.chain()).unwrap();
            d.set_capacity_policy(CapacityPolicy::full(Arc::clone(&ix), "n"));
            // the shadow disk starts as whatever generation populated
            let mut shadow = vec![0u8; DISK as usize];
            d.read(0, &mut shadow).unwrap();
            for i in 0..40u64 {
                match rng.below(5) {
                    4 => {
                        // unaligned write through the CoW path, possibly
                        // crossing zero/compressed/shared clusters
                        let len = (1 + rng.below(2 * CS - 2)) as usize;
                        let off = rng.below(DISK - len as u64);
                        let mut b = vec![0u8; len];
                        rng.fill_bytes(&mut b);
                        d.write(off, &b).unwrap();
                        shadow[off as usize..][..len].copy_from_slice(&b);
                    }
                    k => {
                        let voff = (rng.below(CLUSTERS) * CS) as usize;
                        let data: Vec<u8> = match k {
                            // all-zero: OFLAG_ZERO
                            0 => vec![0u8; CS as usize],
                            // constant from a 3-value set: compress on
                            // first sight, dedup on repeats
                            1 => vec![0x40 | (i % 3) as u8; CS as usize],
                            // copy of existing content: dedup against
                            // the seeded base or an earlier write
                            2 => shadow[(rng.below(CLUSTERS) * CS) as usize..]
                                [..CS as usize]
                                .to_vec(),
                            // fresh incompressible content
                            _ => {
                                let mut b = vec![0u8; CS as usize];
                                rng.fill_bytes(&mut b);
                                b
                            }
                        };
                        d.write(voff as u64, &data).unwrap();
                        shadow[voff..][..CS as usize].copy_from_slice(&data);
                    }
                }
                // immediate spot-check of a random range
                let len = (1 + rng.below(3 * CS)) as usize;
                let off = rng.below(DISK - len as u64) as usize;
                let mut r = vec![0u8; len];
                d.read(off as u64, &mut r).unwrap();
                assert_eq!(r, &shadow[off..off + len], "stamped={stamped} op={i}");
            }
            d.flush().unwrap();
            let mut whole = vec![0u8; DISK as usize];
            d.read(0, &mut whole).unwrap();
            assert!(whole == shadow, "stamped={stamped}: full-disk sweep diverged");
            let report = qcheck::check_chain(d.chain()).unwrap();
            assert!(
                report.is_clean() && report.leaked_clusters == 0,
                "stamped={stamped}: {:?} leaks={}",
                report.errors,
                report.leaked_clusters
            );
        }
    });
}

/// Refcounts gate reclaim: overwriting one referent of a shared extent
/// must not disturb the other; releasing the LAST referent frees the
/// cluster instead of leaking it.
#[test]
fn shared_extent_is_not_reclaimed_while_referenced() {
    let clock = VirtClock::new();
    let chain = chain_on("sh", true, 7, 0.0, 1, &clock);
    let ix = Arc::new(DedupIndex::new());
    let mut d = ScalableDriver::new(
        chain,
        CacheConfig::new(16, 128 << 10),
        clock,
        CostModel::default(),
        MemoryAccountant::new(),
    );
    d.set_capacity_policy(CapacityPolicy::full(Arc::clone(&ix), "n"));
    // incompressible content so the share is a plain refcounted cluster
    let mut x = vec![0u8; CS as usize];
    Rng::new(0xF00D).fill_bytes(&mut x);
    d.write(0, &x).unwrap(); // declares the extent
    d.write(3 * CS, &x).unwrap(); // shares it
    let s = ix.node_stats("n");
    assert_eq!((s.extents, s.refs), (1, 2), "one extent, two referents");

    // overwrite the declarer: must CoW, not write in place
    let mut y = vec![0u8; CS as usize];
    Rng::new(0xBEE5).fill_bytes(&mut y);
    d.write(0, &y).unwrap();
    let mut r = vec![0u8; CS as usize];
    d.read(3 * CS, &mut r).unwrap();
    assert_eq!(r, x, "shared extent reclaimed while still referenced");
    d.read(0, &mut r).unwrap();
    assert_eq!(r, y);
    let s = ix.node_stats("n");
    assert_eq!((s.extents, s.refs), (2, 2), "x (1 ref) + freshly declared y");

    // release the last referent of x: the extent retires and its
    // cluster is freed, not leaked
    d.write(3 * CS, &vec![0u8; CS as usize]).unwrap();
    d.flush().unwrap();
    let s = ix.node_stats("n");
    assert_eq!((s.extents, s.refs), (1, 1), "only y's extent remains");
    let report = qcheck::check_chain(d.chain()).unwrap();
    assert!(
        report.is_clean() && report.leaked_clusters == 0,
        "{:?} leaks={}",
        report.errors,
        report.leaked_clusters
    );
}

/// The golden-image pattern: after launch seeds the index from the
/// immutable base, a guest rewrite of base content becomes a remote
/// reference — no new cluster in the active volume.
#[test]
fn golden_rewrite_shares_base_extent_without_allocating() {
    let clock = VirtClock::new();
    let chain = chain_on("gb", true, 0x601D, 0.5, 2, &clock);
    let ix = Arc::new(DedupIndex::new());
    let mut d = ScalableDriver::new(
        chain,
        CacheConfig::new(16, 128 << 10),
        clock,
        CostModel::default(),
        MemoryAccountant::new(),
    );
    seed_chain(&ix, "n", d.chain()).unwrap();
    d.set_capacity_policy(CapacityPolicy::full(Arc::clone(&ix), "n"));

    // find a vcluster owned by the base (not shadowed) and a hole
    let base = Arc::clone(&d.chain().images()[0]);
    let active = Arc::clone(d.chain().active());
    let (mut src, mut hole) = (None, None);
    for vc in 0..CLUSTERS {
        let b = base.l2_entry(vc).unwrap();
        let a = active.l2_entry(vc).unwrap();
        if a.is_zero() && b.is_allocated_here() && !b.is_zero_cluster() && !b.is_compressed()
        {
            src = src.or(Some(vc));
        }
        if a.is_zero() && b.is_zero() {
            hole = hole.or(Some(vc));
        }
    }
    let (src, hole) = (
        src.expect("seeded chain has a base-owned cluster"),
        hole.expect("seeded chain has a hole"),
    );
    let mut golden = vec![0u8; CS as usize];
    d.read(src * CS, &mut golden).unwrap();

    // prime the hole's L2 table and refcount blocks with a throwaway
    // allocation so the probe below measures only the dedup write
    d.write(hole * CS, &[1u8; 4]).unwrap();
    d.flush().unwrap();
    let before = d.chain().active().backend().stored_bytes();

    d.write(hole * CS, &golden).unwrap();
    d.flush().unwrap();
    let e = d.chain().active().l2_entry(hole).unwrap();
    assert!(
        e.0 != 0 && !e.is_allocated_here() && !e.is_zero_cluster() && !e.is_compressed(),
        "rewrite of golden content must become a remote reference: {e:?}"
    );
    assert!(
        d.chain().active().backend().stored_bytes() <= before,
        "a dedup'd write must not grow the active volume"
    );
    let mut r = vec![0u8; CS as usize];
    d.read(hole * CS, &mut r).unwrap();
    assert_eq!(r, golden, "shared read is bit-identical");
    let report = qcheck::check_chain(d.chain()).unwrap();
    assert!(report.is_clean(), "{:?}", report.errors);
}
