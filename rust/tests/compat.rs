//! Backward compatibility (§5.1), both directions:
//! * an SQEMU-created (stamped) chain must be fully readable by the
//!   *vanilla* driver — the extension lives in bits vanilla ignores;
//! * a vanilla chain must be fully readable by the *SQEMU* driver
//!   (degraded, correction-driven path), and `convert_to_sqemu` must
//!   upgrade it to the fast path.

use sqemu::cache::CacheConfig;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::Driver;
use std::collections::HashMap;
use std::sync::Arc;

const CS: u64 = 64 << 10;
const VCLUSTERS: u64 = 48;

struct Setup {
    node: Arc<StorageNode>,
    clock: Arc<VirtClock>,
    active: String,
    model: HashMap<u64, Vec<u8>>,
}

fn build(stamped: bool, seed: u64) -> Setup {
    let clock = VirtClock::new();
    let node = StorageNode::new("s", clock.clone(), CostModel::default());
    let geom = Geometry::new(16, VCLUSTERS * CS).unwrap();
    let flags = if stamped { FEATURE_BFI } else { 0 };
    let b = node.create_file("img-0").unwrap();
    let img = Image::create("img-0", b, geom, flags, 0, None, DataMode::Real).unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    let mut rng = Rng::new(seed);
    let mut model = HashMap::new();
    for layer in 0..4 {
        for _ in 0..8 {
            let vc = rng.below(VCLUSTERS);
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            let mut data = vec![0u8; CS as usize];
            rng.fill_bytes(&mut data);
            img.write_data(off, 0, &data).unwrap();
            let stamp = if stamped { Some(img.chain_index()) } else { None };
            img.set_l2_entry(vc, L2Entry::local(off, stamp)).unwrap();
            model.insert(vc, data);
        }
        let name = format!("img-{}", layer + 1);
        if stamped {
            snapshot::snapshot_sqemu(&mut chain, &node, &name).unwrap();
        } else {
            snapshot::snapshot_vanilla(&mut chain, &node, &name).unwrap();
        }
    }
    Setup { node, clock, active: chain.active().name.clone(), model }
}

fn verify_driver(d: &mut dyn Driver, model: &HashMap<u64, Vec<u8>>) {
    let mut buf = vec![0u8; CS as usize];
    for vc in 0..VCLUSTERS {
        d.read(vc * CS, &mut buf).unwrap();
        match model.get(&vc) {
            Some(data) => assert_eq!(&buf, data, "vc={vc}"),
            None => assert!(buf.iter().all(|&b| b == 0), "vc={vc} not zero"),
        }
    }
}

#[test]
fn vanilla_driver_reads_sqemu_images() {
    let s = build(true, 101);
    let mut d = VanillaDriver::new(
        Chain::open(&s.node, &s.active, DataMode::Real).unwrap(),
        CacheConfig::new(32, 256 << 10),
        s.clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    verify_driver(&mut d, &s.model);
}

#[test]
fn sqemu_driver_reads_vanilla_images() {
    let s = build(false, 202);
    let mut d = ScalableDriver::new(
        Chain::open(&s.node, &s.active, DataMode::Real).unwrap(),
        CacheConfig::new(32, 256 << 10),
        s.clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    verify_driver(&mut d, &s.model);
}

#[test]
fn convert_upgrades_vanilla_chain_to_fast_path() {
    let s = build(false, 303);
    let chain = Chain::open(&s.node, &s.active, DataMode::Real).unwrap();
    let stamped = snapshot::convert_to_sqemu(&chain).unwrap();
    assert_eq!(stamped as usize, s.model.len());
    // after conversion the active volume resolves everything alone
    for (vc, _) in &s.model {
        let e = chain.active().l2_entry(*vc).unwrap();
        assert!(
            e.sqemu_view(chain.active().chain_index()).is_some(),
            "vc={vc} unstamped after convert"
        );
    }
    // content still correct through both drivers
    let mut d = ScalableDriver::new(
        Chain::open(&s.node, &s.active, DataMode::Real).unwrap(),
        CacheConfig::new(32, 256 << 10),
        s.clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    verify_driver(&mut d, &s.model);
    let mut v = VanillaDriver::new(
        Chain::open(&s.node, &s.active, DataMode::Real).unwrap(),
        CacheConfig::new(32, 256 << 10),
        s.clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    verify_driver(&mut v, &s.model);
}

#[test]
fn sqemu_snapshot_of_stamped_chain_readable_by_vanilla() {
    // full §5.4 snapshot on a stamped chain, then vanilla-driver read
    let s = build(true, 404);
    let mut chain = Chain::open(&s.node, &s.active, DataMode::Real).unwrap();
    snapshot::snapshot_sqemu(&mut chain, &s.node, "img-final").unwrap();
    let mut d = VanillaDriver::new(
        Chain::open(&s.node, "img-final", DataMode::Real).unwrap(),
        CacheConfig::new(32, 256 << 10),
        s.clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    verify_driver(&mut d, &s.model);
}

#[test]
fn mixed_chain_vanilla_snapshot_on_sqemu_base() {
    // provider converts mid-chain: sqemu snapshots, then a vanilla one
    let s = build(true, 505);
    let mut chain = Chain::open(&s.node, &s.active, DataMode::Real).unwrap();
    snapshot::snapshot_vanilla(&mut chain, &s.node, "img-mixed").unwrap();
    // the active volume is now unstamped: sqemu driver must degrade
    let mut d = ScalableDriver::new(
        Chain::open(&s.node, "img-mixed", DataMode::Real).unwrap(),
        CacheConfig::new(32, 256 << 10),
        s.clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    verify_driver(&mut d, &s.model);
}
