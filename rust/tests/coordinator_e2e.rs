//! Coordinator end-to-end: multi-VM fleet over multiple storage nodes,
//! concurrent guest I/O, live snapshots, streaming, placement and bulk
//! translation — the L3 integration surface.

use sqemu::cache::CacheConfig;
use sqemu::chaingen::ChainSpec;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::qcow::image::DataMode;
use sqemu::vdisk::DriverKind;

fn vm_cfg(kind: DriverKind, chain_len: usize, prefix: &str) -> VmConfig {
    VmConfig {
        driver: kind,
        cache: CacheConfig::new(64, 256 << 10),
        chain: VmChain::Generate(ChainSpec {
            disk_size: 16 << 20,
            chain_len,
            populated: 0.4,
            stamped: kind == DriverKind::Scalable,
            data_mode: DataMode::Real,
            prefix: prefix.into(),
            ..Default::default()
        }),
    }
}

#[test]
fn fleet_reads_writes_and_snapshots() {
    let coord = Coordinator::with_fresh_nodes(2).unwrap();
    let a = coord
        .launch_vm("vm-a", vm_cfg(DriverKind::Scalable, 3, "a"))
        .unwrap();
    let b = coord
        .launch_vm("vm-b", vm_cfg(DriverKind::Vanilla, 2, "b"))
        .unwrap();
    assert_eq!(coord.vm_names(), vec!["vm-a", "vm-b"]);

    // guest I/O through both VMs
    a.write(100, vec![7u8; 64]).unwrap();
    b.write(200, vec![9u8; 64]).unwrap();
    assert_eq!(a.read(100, 64).unwrap(), vec![7u8; 64]);
    assert_eq!(b.read(200, 64).unwrap(), vec![9u8; 64]);

    // live snapshot of vm-a; writes continue afterwards
    let untouched_before = a.read(164, 8).unwrap();
    let snap_ns = coord.snapshot_vm("vm-a", "a-snap-1").unwrap();
    let _ = snap_ns; // virtual-time duration of the pause window
    a.write(100, vec![8u8; 64]).unwrap();
    assert_eq!(a.read(100, 64).unwrap(), vec![8u8; 64]);
    // pre-snapshot data still visible where not overwritten
    assert_eq!(a.read(164, 8).unwrap(), untouched_before);

    let stats = coord.vm_stats("vm-a").unwrap();
    assert!(stats.reads >= 2 && stats.writes >= 2);
    assert_eq!(stats.snapshots, 1);
    coord.shutdown();
}

#[test]
fn concurrent_clients_one_vm() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    coord
        .launch_vm("vm", vm_cfg(DriverKind::Scalable, 2, "c"))
        .unwrap();
    let mut handles = vec![];
    for t in 0..4u64 {
        let client = coord.client("vm").unwrap();
        handles.push(std::thread::spawn(move || {
            for i in 0..50u64 {
                // each thread owns a disjoint cluster-aligned region
                let vc = t * 60 + (i % 32);
                let voff = vc * (64 << 10);
                let val = vec![(t as u8 + 1) * 10 + (i % 10) as u8; 32];
                client.write(voff, val.clone()).unwrap();
                assert_eq!(client.read(voff, 32).unwrap(), val);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = coord.vm_stats("vm").unwrap();
    assert_eq!(stats.writes, 200);
    assert_eq!(stats.reads, 200);
    coord.shutdown();
}

#[test]
fn streaming_a_running_vm_preserves_content() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    let c = coord
        .launch_vm("vm", vm_cfg(DriverKind::Scalable, 6, "s"))
        .unwrap();
    // record pre-stream content at a few offsets
    let offsets: Vec<u64> = (0..20).map(|i| i * 700_000).collect();
    let before: Vec<Vec<u8>> = offsets.iter().map(|&o| c.read(o, 64).unwrap()).collect();

    let report = coord.stream_vm("vm", 1, 3).unwrap();
    assert_eq!(report.len_after, report.len_before - 2);
    assert_eq!(report.planned_clusters, report.copied_clusters);

    for (i, &o) in offsets.iter().enumerate() {
        assert_eq!(c.read(o, 64).unwrap(), before[i], "offset {o}");
    }
    let stats = coord.vm_stats("vm").unwrap();
    assert_eq!(stats.streams, 1);
    coord.shutdown();
}

#[test]
fn placement_spreads_files_and_bulk_translation_works() {
    let coord = Coordinator::with_fresh_nodes(3).unwrap();
    coord
        .launch_vm("vm", vm_cfg(DriverKind::Scalable, 8, "p"))
        .unwrap();
    let usage = coord.nodes.usage();
    let populated = usage.iter().filter(|(_, u)| *u > 0).count();
    assert!(populated >= 2, "files all on one node: {usage:?}");

    // bulk translation against the live chain (control-plane path)
    coord.client("vm").unwrap().flush().unwrap();
    let chain =
        sqemu::qcow::Chain::open(coord.nodes.as_ref(), "p-7", DataMode::Real).unwrap();
    let bt = coord.translator();
    let plan = bt.prefetch_plan(&chain, 128).unwrap();
    // populated ~0.4 -> a decent share of the first 128 clusters resolve
    assert!(plan.len() > 10, "plan too small: {}", plan.len());
    coord.shutdown();
}

#[test]
fn vm_lifecycle_errors() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    coord
        .launch_vm("vm", vm_cfg(DriverKind::Vanilla, 1, "x"))
        .unwrap();
    assert!(coord
        .launch_vm("vm", vm_cfg(DriverKind::Vanilla, 1, "y"))
        .is_err());
    assert!(coord.client("ghost").is_err());
    assert!(coord.stop_vm("ghost").is_err());
    coord.stop_vm("vm").unwrap();
    assert!(coord.client("vm").is_err());
}
