//! Crash-everywhere property suite: power-cut a randomized workload at
//! EVERY durable-write index (clean cuts and sector-torn cuts), on both
//! drivers, then reopen + `qcheck --repair` and assert the chain is
//! clean (zero hard inconsistencies, zero leaked clusters) with every
//! byte acknowledged before the last successful flush bit-identical.
//!
//! On failure, the failing (driver, seed, cut index, tear) tuple is
//! written to `$CRASH_REPRO_PATH` (default `crash_repro.txt`) so CI can
//! attach the shrunken repro to a bug report.

use sqemu::blockjob::{BlockJob, LiveStreamJob};
use sqemu::cache::CacheConfig;
use sqemu::chaingen::ChainSpec;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::dedup::{CapacityPolicy, DedupContext, DedupIndex};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{qcheck, snapshot, Chain};
use sqemu::storage::fault::{FaultInjector, FaultStore, SECTOR};
use sqemu::storage::store::FileStore;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::{Driver, DriverKind};
use std::sync::Arc;

const CLUSTER_BITS: u32 = 12; // 4 KiB clusters
const CS: usize = 1 << CLUSTER_BITS;
const VCLUSTERS: usize = 64;
const DISK: usize = VCLUSTERS * CS; // 256 KiB
const N_OPS: usize = 18;

fn geom() -> Geometry {
    Geometry::new(CLUSTER_BITS, DISK as u64).unwrap()
}

fn build_driver(kind: DriverKind, chain: Chain, clock: &Arc<VirtClock>) -> Box<dyn Driver> {
    let cache = CacheConfig::new(16, 32 << 10);
    let mut driver: Box<dyn Driver> = match kind {
        DriverKind::Scalable => Box::new(ScalableDriver::new(
            chain,
            cache,
            Arc::clone(clock),
            CostModel::default(),
            MemoryAccountant::new(),
        )),
        DriverKind::Vanilla => Box::new(VanillaDriver::new(
            chain,
            cache,
            Arc::clone(clock),
            CostModel::default(),
            MemoryAccountant::new(),
        )),
    };
    // capacity subsystem on: the crash surface must include zero,
    // compressed and dedup-shared entries. The index is volatile by
    // design (a recovered coordinator starts with an empty one), so
    // every replay gets its own.
    driver.set_capacity_policy(CapacityPolicy {
        zero_detect: true,
        compress: true,
        dedup: Some(DedupContext { index: Arc::new(DedupIndex::new()), node: "crash".into() }),
    });
    driver
}

/// End state of one (possibly crashed) workload replay: the byte-level
/// oracle of what MUST survive (`durable`/`durable_mask`, committed at
/// each acknowledged flush) and the last acknowledged chain head.
struct Outcome {
    durable: Vec<u8>,
    durable_mask: Vec<bool>,
    /// Bytes overwritten in place AFTER the last acknowledged flush:
    /// like a real disk, a crash may leave old, new, or a sector-level
    /// mix there, so the oracle must not assert their content.
    overwritten: Vec<bool>,
    head: Option<String>,
    crashed: bool,
}

/// Replay the seeded workload (guest writes, flushes, snapshots, a live
/// stream job with interleaved writes) until it completes or the power
/// cut kills it. Every driver acknowledgment updates the model; every
/// acknowledged flush commits the model to the durable oracle.
fn run_workload(kind: DriverKind, seed: u64, store: &Arc<FaultStore>) -> Outcome {
    let geom = geom();
    let cs = geom.cluster_size();
    let mut model = vec![0u8; DISK];
    let mut mask = vec![false; DISK];
    let mut durable = vec![0u8; DISK];
    let mut durable_mask = vec![false; DISK];
    let mut overwritten = vec![false; DISK];
    let mut head: Option<String> = None;
    let mut rng = Rng::new(seed);

    let result = (|| -> anyhow::Result<()> {
        let flags = if kind == DriverKind::Scalable { FEATURE_BFI } else { 0 };
        let backend = store.create_file("img-0")?;
        let img = Image::create("img-0", backend, geom, flags, 0, None, DataMode::Real)?;
        head = Some("img-0".to_string());
        let chain = Chain::new(Arc::new(img))?;
        let clock = VirtClock::new();
        let mut driver = build_driver(kind, chain, &clock);
        let mut snap_no = 0usize;

        // deterministic skeleton (snapshots at 4 and 9, the live stream
        // at 12) with randomized writes/flushes in between, so every
        // seed exercises snapshot creation, a mid-chain stream job and
        // plain guest I/O
        for opi in 0..N_OPS {
            let pick = match opi {
                4 | 9 => 75u64,  // snapshot
                12 => 90,        // live stream job
                _ => rng.below(70),
            };
            if pick < 55 {
                if rng.chance(0.25) {
                    // full-cluster capacity write: all-zero clusters
                    // exercise OFLAG_ZERO, constant fills the compress
                    // and dedup-share paths (repeats of 0x11/0x22 hit
                    // the content index)
                    let vc = rng.below(geom.num_vclusters());
                    let val = [0u8, 0x11, 0x22][rng.below(3) as usize];
                    let voff = (vc * cs) as usize;
                    let data = vec![val; CS];
                    driver.write(voff as u64, &data)?;
                    model[voff..voff + CS].copy_from_slice(&data);
                    mask[voff..voff + CS].fill(true);
                    overwritten[voff..voff + CS].fill(true);
                } else {
                    // guest write within one cluster
                    let vc = rng.below(geom.num_vclusters());
                    let off = rng.below(cs - 600);
                    let len = (rng.below(512) + 1) as usize;
                    let val = (opi as u8 ^ vc as u8).wrapping_mul(37).wrapping_add(1);
                    let voff = (vc * cs + off) as usize;
                    let data = vec![val; len];
                    driver.write(voff as u64, &data)?;
                    model[voff..voff + len].copy_from_slice(&data);
                    mask[voff..voff + len].fill(true);
                    overwritten[voff..voff + len].fill(true);
                }
            } else if pick < 70 {
                // guest FLUSH: once acknowledged, everything written so
                // far is promised to survive any crash
                driver.flush()?;
                durable.copy_from_slice(&model);
                durable_mask.copy_from_slice(&mask);
                overwritten.fill(false);
            } else if pick < 85 {
                // paused-VM snapshot, coordinator-style
                driver.flush()?;
                durable.copy_from_slice(&model);
                durable_mask.copy_from_slice(&mask);
                overwritten.fill(false);
                snap_no += 1;
                let name = format!("img-{snap_no}");
                match kind {
                    DriverKind::Scalable => {
                        snapshot::snapshot_sqemu(driver.chain_mut(), &**store, &name)?
                    }
                    DriverKind::Vanilla => {
                        snapshot::snapshot_vanilla(driver.chain_mut(), &**store, &name)?
                    }
                }
                driver.reopen()?;
                head = Some(name);
            } else {
                // live stream job, interleaved with guest writes
                if driver.chain().len() < 2 {
                    continue;
                }
                let fence = Arc::clone(driver.fence());
                fence.begin();
                let mut job = LiveStreamJob::new(driver.chain(), Arc::clone(&fence));
                loop {
                    let inc = job.run_increment(driver.chain_mut(), 8)?;
                    if rng.chance(0.5) {
                        let vc = rng.below(geom.num_vclusters());
                        let val = 0xC0u8 ^ vc as u8;
                        let voff = (vc * cs) as usize;
                        let data = vec![val; 128];
                        driver.write(voff as u64, &data)?;
                        model[voff..voff + 128].copy_from_slice(&data);
                        mask[voff..voff + 128].fill(true);
                        overwritten[voff..voff + 128].fill(true);
                    }
                    if inc.complete {
                        break;
                    }
                }
                // completion protocol, JobRunner-style
                driver.flush()?;
                durable.copy_from_slice(&model);
                durable_mask.copy_from_slice(&mask);
                overwritten.fill(false);
                job.finalize(driver.chain_mut())?;
                driver.reopen()?;
                fence.end();
            }
        }
        driver.flush()?;
        durable.copy_from_slice(&model);
        durable_mask.copy_from_slice(&mask);
        overwritten.fill(false);
        Ok(())
    })();

    Outcome { durable, durable_mask, overwritten, head, crashed: result.is_err() }
}

/// Write the failing tuple where CI can pick it up, then panic with it.
fn fail_repro(kind: DriverKind, seed: u64, cut: u64, tear: Option<u64>, msg: &str) -> ! {
    let path = std::env::var("CRASH_REPRO_PATH")
        .unwrap_or_else(|_| "crash_repro.txt".to_string());
    let note = format!(
        "crash-recovery failure\ndriver={} seed={seed:#x} cut_at_event={cut} \
         tear_keep_bytes={tear:?}\n{msg}\n(cache eviction order can vary \
         between processes; the cut index may need a small scan around the \
         recorded value)\n",
        kind.name(),
    );
    let _ = std::fs::write(&path, &note);
    panic!("{note}");
}

/// Power back on, reopen the acknowledged head, repair, and assert the
/// crash-consistency contract; then GC unreachable files and re-verify.
fn verify_recovery(
    store: &Arc<FaultStore>,
    kind: DriverKind,
    seed: u64,
    cut: u64,
    tear: Option<u64>,
    out: &Outcome,
) {
    store.injector().revive();
    let Some(head) = &out.head else { return };

    // 1. the head must reopen: headers are crash-atomic by construction
    let chain = match Chain::open(&**store, head, DataMode::Real) {
        Ok(c) => c,
        Err(e) => fail_repro(kind, seed, cut, tear, &format!("reopen failed: {e:#}")),
    };
    // 2. repair must succeed and leave a fully clean chain
    if let Err(e) = qcheck::repair_chain(&chain) {
        fail_repro(kind, seed, cut, tear, &format!("repair failed: {e:#}"));
    }
    let report = match qcheck::check_chain(&chain) {
        Ok(r) => r,
        Err(e) => fail_repro(kind, seed, cut, tear, &format!("qcheck failed: {e:#}")),
    };
    if !report.is_clean() || report.leaked_clusters != 0 {
        fail_repro(
            kind,
            seed,
            cut,
            tear,
            &format!(
                "post-repair chain not clean: {} errors, {} leaks: {:?}",
                report.errors.len(),
                report.leaked_clusters,
                report.errors
            ),
        );
    }
    // 3. every acknowledged-flushed byte is intact
    let clock = VirtClock::new();
    let mut driver = build_driver(kind, chain, &clock);
    let mut buf = vec![0u8; CS];
    for vc in 0..VCLUSTERS {
        if let Err(e) = driver.read((vc * CS) as u64, &mut buf) {
            fail_repro(kind, seed, cut, tear, &format!("read vc {vc} failed: {e:#}"));
        }
        for i in 0..CS {
            let g = vc * CS + i;
            if out.durable_mask[g] && !out.overwritten[g] && buf[i] != out.durable[g] {
                fail_repro(
                    kind,
                    seed,
                    cut,
                    tear,
                    &format!(
                        "durable byte lost at voff {g}: got {:#x}, want {:#x}",
                        buf[i], out.durable[g]
                    ),
                );
            }
        }
    }
    drop(driver);

    // 4. recovery GC: drop every file the head's backing walk cannot
    //    reach (orphans of interrupted creates/streams) and re-verify
    let mut reachable = std::collections::HashSet::new();
    if let Err(e) = sqemu::gc::walk_backing(&**store, head, &mut reachable) {
        fail_repro(kind, seed, cut, tear, &format!("backing walk failed: {e:#}"));
    }
    for name in store.file_names() {
        if !reachable.contains(&name) {
            if let Err(e) = store.delete_file(&name) {
                fail_repro(kind, seed, cut, tear, &format!("gc delete failed: {e:#}"));
            }
        }
    }
    let chain = match Chain::open(&**store, head, DataMode::Real) {
        Ok(c) => c,
        Err(e) => {
            fail_repro(kind, seed, cut, tear, &format!("post-gc reopen failed: {e:#}"))
        }
    };
    match qcheck::check_chain(&chain) {
        Ok(r) if r.is_clean() => {}
        Ok(r) => fail_repro(
            kind,
            seed,
            cut,
            tear,
            &format!("post-gc chain dirty: {:?}", r.errors),
        ),
        Err(e) => fail_repro(kind, seed, cut, tear, &format!("post-gc qcheck: {e:#}")),
    }
}

/// The tentpole property: crash at EVERY durable-event index (clean and
/// sector-torn), reopen + repair, and the contract holds.
fn crash_everywhere(kind: DriverKind, seed: u64) {
    // fault-free pass: bounds the cut range and sanity-checks the oracle
    let injector = FaultInjector::new();
    let store = Arc::new(FaultStore::new(Arc::clone(&injector)));
    let out = run_workload(kind, seed, &store);
    assert!(!out.crashed, "fault-free run must complete");
    let n = injector.events(); // before verification adds its own events
    verify_recovery(&store, kind, seed, u64::MAX, None, &out);
    assert!(n > 60, "workload too small to be interesting: {n} events");

    let step = if n > 240 { 3 } else { 1 };
    let mut k = 0u64;
    while k < n {
        // clean power cut at event k
        let injector = FaultInjector::new();
        let store = Arc::new(FaultStore::new(Arc::clone(&injector)));
        injector.arm(k, None);
        let out = run_workload(kind, seed, &store);
        verify_recovery(&store, kind, seed, k, None, &out);

        // sector-torn cut at event k (sectors are atomic; multi-sector
        // writes can persist any sector prefix)
        let keep = SECTOR * (k % 8);
        let injector = FaultInjector::new();
        let store = Arc::new(FaultStore::new(Arc::clone(&injector)));
        injector.arm(k, Some(keep));
        let out = run_workload(kind, seed, &store);
        verify_recovery(&store, kind, seed, k, Some(keep), &out);

        k += step;
    }
}

#[test]
fn crash_everywhere_scalable() {
    crash_everywhere(DriverKind::Scalable, 0xC0FFEE);
}

#[test]
fn crash_everywhere_vanilla() {
    crash_everywhere(DriverKind::Vanilla, 0x5EED_BEEF);
}

// ---------------------------------------------------------------- header

/// Satellite: `set_feature_bfi` under byte-granular torn writes — the
/// header flip is atomic (old-valid or new-valid, never garbage), even
/// without sector atomicity, thanks to the checksummed double slot.
#[test]
fn feature_flip_is_atomic_under_arbitrary_tearing() {
    let make = |injector: &Arc<FaultInjector>| -> (Arc<FaultStore>, Image) {
        let store = Arc::new(FaultStore::new(Arc::clone(injector)));
        let b = store.create_file("img").unwrap();
        let img = Image::create("img", b, geom(), 0, 0, None, DataMode::Real).unwrap();
        (store, img)
    };
    for tear in 0..96u64 {
        let injector = FaultInjector::new();
        let (store, img) = make(&injector);
        injector.arm(0, Some(tear));
        let r = img.set_feature_bfi();
        injector.revive();
        let reopened =
            Image::open("img", store.open_file("img").unwrap(), DataMode::Real)
                .unwrap_or_else(|e| panic!("tear={tear}: header unopenable: {e:#}"));
        if r.is_ok() {
            assert!(reopened.has_bfi(), "tear={tear}: acknowledged flip lost");
        } else {
            // old-valid or new-valid — never a half-state beyond the flag
            assert_eq!(reopened.chain_index(), 0, "tear={tear}");
            assert_eq!(reopened.backing_name(), None, "tear={tear}");
        }
    }
}

/// Satellite: `update_header` (chain relink) under torn writes — the
/// reopened image shows the old link or the new link in full.
#[test]
fn update_header_is_atomic_under_arbitrary_tearing() {
    for tear in 0..96u64 {
        let injector = FaultInjector::new();
        let store = Arc::new(FaultStore::new(Arc::clone(&injector)));
        let b = store.create_file("img").unwrap();
        let img = Image::create(
            "img",
            b,
            geom(),
            FEATURE_BFI,
            2,
            Some("old-parent"),
            DataMode::Real,
        )
        .unwrap();
        injector.arm(0, Some(tear));
        let r = img.update_header(1, Some("new-parent"));
        injector.revive();
        let reopened =
            Image::open("img", store.open_file("img").unwrap(), DataMode::Real)
                .unwrap_or_else(|e| panic!("tear={tear}: header unopenable: {e:#}"));
        let link = (reopened.chain_index(), reopened.backing_name());
        if r.is_ok() {
            assert_eq!(link, (1, Some("new-parent".to_string())), "tear={tear}");
        } else {
            assert!(
                link == (2, Some("old-parent".to_string()))
                    || link == (1, Some("new-parent".to_string())),
                "tear={tear}: torn header mixed states: {link:?}"
            );
        }
    }
}

/// Header updates keep alternating slots: tearing the SECOND update must
/// fall back to the durable first update, not the original.
#[test]
fn torn_second_update_falls_back_to_first() {
    let injector = FaultInjector::new();
    let store = Arc::new(FaultStore::new(Arc::clone(&injector)));
    let b = store.create_file("img").unwrap();
    let img =
        Image::create("img", b, geom(), 0, 0, None, DataMode::Real).unwrap();
    img.update_header(1, Some("first")).unwrap();
    injector.arm(0, Some(16));
    assert!(img.update_header(2, Some("second")).is_err());
    injector.revive();
    let reopened =
        Image::open("img", store.open_file("img").unwrap(), DataMode::Real).unwrap();
    assert_eq!(reopened.chain_index(), 1);
    assert_eq!(reopened.backing_name().as_deref(), Some("first"));
}

// ----------------------------------------------------------- coordinator

/// The coordinator's recovery pass repairs a node's images before guest
/// I/O is admitted, and `launch_vm` refuses nothing afterwards.
#[test]
fn coordinator_recover_repairs_node_images_before_launch() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    let geom = geom();
    // build a 2-deep chain directly on the node, then corrupt it the way
    // a crash would: a dangling mapping and a leaked cluster
    {
        let b = coord.nodes.create_file("img-0").unwrap();
        let img = Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real)
            .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        let active = Arc::clone(chain.active());
        let off = active.alloc_data_cluster().unwrap();
        active.write_data(off, 0, &[0x5A; 64]).unwrap();
        active.set_l2_entry(0, L2Entry::local(off, Some(0))).unwrap();
        snapshot::snapshot_sqemu(&mut chain, coord.nodes.as_ref(), "img-1").unwrap();
        let active = Arc::clone(chain.active());
        active
            .set_l2_entry(7, L2Entry::local(1 << 40, Some(1)))
            .unwrap();
        active.alloc_data_cluster().unwrap(); // leak
    }
    let report = coord.recover();
    assert_eq!(report.images_checked, 2, "{report:?}");
    assert!(report.images_repaired >= 1, "{report:?}");
    assert!(report.unopenable.is_empty(), "{report:?}");
    assert_eq!(report.chains_checked, 1);

    let client = coord
        .launch_vm(
            "vm",
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(16, 32 << 10),
                chain: VmChain::Existing {
                    active_name: "img-1".to_string(),
                    data_mode: DataMode::Real,
                },
            },
        )
        .unwrap();
    let got = client.read(0, 64).unwrap();
    assert_eq!(got, vec![0x5A; 64], "repaired chain serves its data");
    assert_eq!(client.read(7 * geom.cluster_size(), 8).unwrap(), vec![0u8; 8]);
    coord.shutdown();
}

/// Satellite: a panicking VM worker no longer takes the fleet down — its
/// own client errors, every other VM and coordinator API keeps working.
#[test]
fn worker_panic_does_not_cascade() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    let spec = |name: &str, seed: u64| VmConfig {
        driver: DriverKind::Scalable,
        cache: CacheConfig::new(16, 32 << 10),
        chain: VmChain::Generate(ChainSpec {
            disk_size: 1 << 20,
            chain_len: 2,
            populated: 0.5,
            stamped: true,
            data_mode: DataMode::Real,
            prefix: name.to_string(),
            seed,
            ..Default::default()
        }),
    };
    let a = coord.launch_vm("vm-a", spec("vm-a", 1)).unwrap();
    let b = coord.launch_vm("vm-b", spec("vm-b", 2)).unwrap();

    // a request no allocator can satisfy panics the worker mid-serve
    assert!(a.read(0, usize::MAX).is_err(), "dead vm errors its own client");
    // the panic is surfaced in the dead VM's stats (poll: the worker
    // records it while unwinding, racing this read)
    let mut panics = 0;
    for _ in 0..200 {
        panics = coord.vm_stats("vm-a").unwrap().worker_panics;
        if panics > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(panics, 1, "worker panic recorded");

    // the fleet lives on: the other VM serves, control plane works
    assert!(b.read(0, 4096).is_ok());
    assert_eq!(coord.vm_names(), vec!["vm-a".to_string(), "vm-b".to_string()]);
    assert!(coord.list_jobs().is_empty());
    let c = coord.launch_vm("vm-c", spec("vm-c", 3)).unwrap();
    assert!(c.read(0, 512).is_ok());
    assert!(coord.vm_stats("vm-b").unwrap().reads >= 1);
    coord.shutdown();
}
