//! The central correctness property of the reproduction: for any chain,
//! the vanilla and SQEMU drivers return byte-identical data and agree
//! with the uncached chain walk — they may only differ in cost.

use sqemu::cache::CacheConfig;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::util::prop::forall;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::Driver;
use std::sync::Arc;

const CS: u64 = 64 << 10;

/// Build a random chain (sqemu- or vanilla-created per `stamped`),
/// returning (node, chain-name, clock) plus the write history.
fn build_chain(
    rng: &mut Rng,
    stamped: bool,
    layers: usize,
    writes_per_layer: usize,
    vclusters: u64,
) -> (Arc<StorageNode>, Arc<VirtClock>, String) {
    let clock = VirtClock::new();
    let node = StorageNode::new("s", clock.clone(), CostModel::default());
    let geom = Geometry::new(16, vclusters * CS).unwrap();
    let flags = if stamped { FEATURE_BFI } else { 0 };
    let b = node.create_file("img-0").unwrap();
    let img = Image::create("img-0", b, geom, flags, 0, None, DataMode::Real).unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    for layer in 0..layers {
        for _ in 0..writes_per_layer {
            let vc = rng.below(vclusters);
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            let mut data = vec![0u8; 256];
            rng.fill_bytes(&mut data);
            img.write_data(off, 0, &data).unwrap();
            let stamp = if stamped { Some(img.chain_index()) } else { None };
            img.set_l2_entry(vc, sqemu::qcow::entry::L2Entry::local(off, stamp))
                .unwrap();
        }
        let name = format!("img-{}", layer + 1);
        if stamped {
            snapshot::snapshot_sqemu(&mut chain, &node, &name).unwrap();
        } else {
            snapshot::snapshot_vanilla(&mut chain, &node, &name).unwrap();
        }
    }
    let active = chain.active().name.clone();
    (node, clock, active)
}

fn drivers_for(
    node: &StorageNode,
    active: &str,
    clock: &Arc<VirtClock>,
) -> (VanillaDriver, ScalableDriver) {
    let cfg = CacheConfig::new(32, 256 << 10);
    let v = VanillaDriver::new(
        Chain::open(node, active, DataMode::Real).unwrap(),
        cfg,
        clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    let s = ScalableDriver::new(
        Chain::open(node, active, DataMode::Real).unwrap(),
        cfg,
        clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    (v, s)
}

#[test]
fn drivers_agree_on_random_sqemu_chains() {
    forall(0xD0D0, 8, |rng| {
        let layers = 1 + rng.below(5) as usize;
        let (node, clock, active) = build_chain(rng, true, layers, 6, 64);
        let (mut v, mut s) = drivers_for(&node, &active, &clock);
        for _ in 0..40 {
            let voff = rng.below(64 * CS - 300);
            let len = 1 + rng.below(300) as usize;
            let mut bv = vec![0u8; len];
            let mut bs = vec![0u8; len];
            v.read(voff, &mut bv).unwrap();
            s.read(voff, &mut bs).unwrap();
            assert_eq!(bv, bs, "voff={voff} len={len}");
        }
    });
}

#[test]
fn drivers_agree_on_random_vanilla_chains() {
    // SQEMU driver on unstamped images: backward-compat fallback path
    forall(0xBEEF, 6, |rng| {
        let layers = 1 + rng.below(4) as usize;
        let (node, clock, active) = build_chain(rng, false, layers, 5, 48);
        let (mut v, mut s) = drivers_for(&node, &active, &clock);
        for _ in 0..30 {
            let voff = rng.below(48 * CS - 100);
            let len = 1 + rng.below(100) as usize;
            let mut bv = vec![0u8; len];
            let mut bs = vec![0u8; len];
            v.read(voff, &mut bv).unwrap();
            s.read(voff, &mut bs).unwrap();
            assert_eq!(bv, bs, "voff={voff} len={len}");
        }
    });
}

#[test]
fn writes_through_one_driver_visible_to_a_fresh_other() {
    forall(0xCAFE, 6, |rng| {
        let (node, clock, active) = build_chain(rng, true, 3, 5, 32);
        let (mut v, _) = drivers_for(&node, &active, &clock);
        for _ in 0..10 {
            let voff = rng.below(32 * CS - 64);
            let len = 1 + rng.below(64) as usize;
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            // write through the vanilla driver, persist, then verify a
            // *freshly opened* scalable driver reads it back (the on-disk
            // format, not the cache, is the interchange medium)
            v.write(voff, &data).unwrap();
            v.flush().unwrap();
            let (_, mut s) = drivers_for(&node, &active, &clock);
            let mut bs = vec![0u8; len];
            s.read(voff, &mut bs).unwrap();
            assert_eq!(bs, data, "scalable sees vanilla write at {voff}");
        }
    });
}

#[test]
fn both_drivers_match_uncached_walk() {
    forall(0x5EED, 6, |rng| {
        let (node, clock, active) = build_chain(rng, true, 4, 8, 64);
        let (mut v, mut s) = drivers_for(&node, &active, &clock);
        let chain = Chain::open(&node, &active, DataMode::Real).unwrap();
        for vc in 0..64u64 {
            let walk = chain.resolve_walk(vc).unwrap();
            let mut bv = vec![0u8; 16];
            let mut bs = vec![0u8; 16];
            v.read(vc * CS, &mut bv).unwrap();
            s.read(vc * CS, &mut bs).unwrap();
            match walk {
                None => {
                    assert_eq!(bv, vec![0u8; 16]);
                    assert_eq!(bs, vec![0u8; 16]);
                }
                Some((bfi, off)) => {
                    let mut expect = vec![0u8; 16];
                    chain.get(bfi).unwrap().read_data(off, 0, &mut expect).unwrap();
                    assert_eq!(bv, expect);
                    assert_eq!(bs, expect);
                }
            }
        }
    });
}
