//! Eq. 1 validation: the measured average lookup cost of the *vanilla*
//! driver tracks the paper's analytic model
//!
//!   Y = [Hit% * T_M + Miss% * (T_D + T_L + T_F) + UnAl% * T_F] * N
//!
//! within model error, and the SQEMU driver's cost is flat in N.

use sqemu::cache::CacheConfig;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::Driver;
use std::sync::Arc;

const CS: u64 = 64 << 10;
const VCLUSTERS: u64 = 512;

/// Chain with valid clusters uniformly distributed over layers (§6.1).
fn build(stamped: bool, layers: usize) -> (Arc<StorageNode>, Arc<VirtClock>, String) {
    let clock = VirtClock::new();
    let node = StorageNode::new("s", clock.clone(), CostModel::default());
    let geom = Geometry::new(16, VCLUSTERS * CS).unwrap();
    let flags = if stamped { FEATURE_BFI } else { 0 };
    let b = node.create_file("img-0").unwrap();
    let img =
        Image::create("img-0", b, geom, flags, 0, None, DataMode::Synthetic).unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    let mut rng = Rng::new(9);
    let mut vcs: Vec<u64> = (0..VCLUSTERS).collect();
    rng.shuffle(&mut vcs);
    let per_layer = VCLUSTERS as usize * 9 / 10 / (layers + 1);
    let mut cursor = 0;
    for layer in 0..=layers {
        for _ in 0..per_layer {
            let vc = vcs[cursor % vcs.len()];
            cursor += 1;
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            let stamp = if stamped { Some(img.chain_index()) } else { None };
            img.set_l2_entry(vc, L2Entry::local(off, stamp)).unwrap();
        }
        if layer < layers {
            let name = format!("img-{}", layer + 1);
            if stamped {
                snapshot::snapshot_sqemu(&mut chain, &node, &name).unwrap();
            } else {
                snapshot::snapshot_vanilla(&mut chain, &node, &name).unwrap();
            }
        }
    }
    (node, clock, chain.active().name.clone())
}

fn mean_lookup_ns(d: &mut dyn Driver) -> (f64, sqemu::metrics::counters::CounterSnapshot) {
    let mut buf = [0u8; 1];
    for vc in 0..VCLUSTERS {
        d.read(vc * CS, &mut buf).unwrap();
    }
    (d.lookup_latency().mean(), d.counters())
}

#[test]
fn vanilla_cost_tracks_eq1_and_grows_linearly() {
    let cost = CostModel::default();
    let mut means = Vec::new();
    for layers in [4usize, 16] {
        let (node, clock, active) = build(false, layers);
        let mut d = VanillaDriver::new(
            Chain::open(&node, &active, DataMode::Synthetic).unwrap(),
            CacheConfig::new(32, 16 << 20),
            clock,
            cost,
            MemoryAccountant::new(),
        );
        let (mean, snap) = mean_lookup_ns(&mut d);
        // Eq. 1 with measured event ratios: per-level cost * levels walked
        let (h, m, u) = snap.ratios();
        let levels = snap.total_lookups() as f64 / VCLUSTERS as f64;
        let eq1 = cost.eq1_avg_lookup_ns(h, m, u, 1) * levels;
        let err = (mean - eq1).abs() / eq1;
        assert!(
            err < 0.5,
            "layers={layers}: measured {mean:.0} vs eq1 {eq1:.0} (err {err:.2})"
        );
        means.push(mean);
    }
    // 4 -> 16 layers: cost should grow clearly (the §4 problem)
    assert!(
        means[1] > means[0] * 2.0,
        "no linear growth: {means:?}"
    );
}

#[test]
fn sqemu_cost_is_flat_in_chain_length() {
    let cost = CostModel::default();
    let mut means = Vec::new();
    for layers in [4usize, 16, 64] {
        let (node, clock, active) = build(true, layers);
        let mut d = ScalableDriver::new(
            Chain::open(&node, &active, DataMode::Synthetic).unwrap(),
            CacheConfig::new(32, 16 << 20),
            clock,
            cost,
            MemoryAccountant::new(),
        );
        let (mean, _) = mean_lookup_ns(&mut d);
        means.push(mean);
    }
    let spread = means.iter().cloned().fold(0.0f64, f64::max)
        / means.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread < 1.5, "sqemu lookup cost not flat: {means:?}");
}
