//! Fleet scale on the sharded data plane: >= 1k concurrent VMs across
//! shard executors must produce bit-identical disk contents to
//! single-threaded execution with per-VM program order preserved; the
//! scheduler must stay fair under a flooding neighbour; and a parked
//! executor must not spin while a paused job waits (the 2ms-poll
//! regression).

use sqemu::cache::CacheConfig;
use sqemu::chaingen::ChainSpec;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{
    Coordinator, CoordinatorConfig, JobSpec, NodeSet, VmConfig,
};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::image::DataMode;
use sqemu::runtime::service::RuntimeService;
use sqemu::storage::node::StorageNode;
use sqemu::vdisk::DriverKind;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const CLUSTER: u64 = 64 << 10;

fn coordinator(nodes: usize, shards: usize) -> Arc<Coordinator> {
    let clock = VirtClock::new();
    let set = (0..nodes)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    Coordinator::new(
        Arc::new(NodeSet::new(set).unwrap()),
        clock,
        CoordinatorConfig { shards, ..Default::default() },
        RuntimeService::try_default(),
    )
}

fn tiny_vm(name: &str, seed: u64, chain_len: usize) -> VmConfig {
    let kind = if seed % 2 == 0 { DriverKind::Scalable } else { DriverKind::Vanilla };
    VmConfig {
        driver: kind,
        cache: CacheConfig::new(8, 16 << 10),
        chain: VmChain::Generate(ChainSpec {
            disk_size: 1 << 20,
            chain_len,
            populated: 0.0,
            stamped: kind == DriverKind::Scalable,
            data_mode: DataMode::Real,
            prefix: name.to_string(),
            seed,
            ..Default::default()
        }),
    }
}

/// The deterministic per-VM op script: cluster-aligned writes including
/// same-offset overwrites (so program order is observable in the final
/// bytes), a vectored burst, a flush, then inline read-back against a
/// shadow model. Returns the shadow: offset -> expected bytes.
fn run_script(
    client: &sqemu::coordinator::VmClient,
    seed: u64,
) -> HashMap<u64, Vec<u8>> {
    let mut shadow: HashMap<u64, Vec<u8>> = HashMap::new();
    let voff = |k: u64| ((seed.wrapping_mul(7) + k * 3) % 14) * CLUSTER;
    for k in 0..4u64 {
        let val = vec![(seed as u8).wrapping_mul(31).wrapping_add(k as u8); 512];
        client.write(voff(k), val.clone()).unwrap();
        shadow.insert(voff(k), val);
    }
    // overwrites: two of the same offsets again with different bytes —
    // only execution in submission order yields these final contents
    for k in 0..2u64 {
        let val = vec![(seed as u8).wrapping_mul(13).wrapping_add(200 + k as u8); 512];
        client.write(voff(k), val.clone()).unwrap();
        shadow.insert(voff(k), val);
    }
    // vectored burst as one ring entry
    let burst: Vec<(u64, Vec<u8>)> = (4..7u64)
        .map(|k| (voff(k), vec![(seed as u8).wrapping_add(77 + k as u8); 256]))
        .collect();
    for (o, v) in &burst {
        shadow.insert(*o, v.clone());
    }
    client.writev(burst).unwrap();
    client.flush().unwrap();
    // inline verification = per-VM ordering proof
    let reqs: Vec<(u64, usize)> =
        shadow.iter().map(|(o, v)| (*o, v.len())).collect();
    let got = client.readv(&reqs).unwrap();
    for ((o, len), buf) in reqs.iter().zip(&got) {
        assert_eq!(buf, &shadow[o], "voff {o} len {len} seed {seed}");
    }
    shadow
}

/// Tentpole acceptance: 1024 VMs spread across the shard pool, driven
/// concurrently from 8 client threads, every disk bit-identical to the
/// shadow (= sequential) model both inline and after the fleet quiesces.
#[test]
fn thousand_vms_bit_identical_across_shards() {
    const FLEET: usize = 1024;
    const THREADS: usize = 8;
    let coord = coordinator(4, 4);
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut shadows = Vec::new();
            for i in (t..FLEET).step_by(THREADS) {
                let name = format!("vm-{i:04}");
                let client =
                    coord.launch_vm(&name, tiny_vm(&name, i as u64, 1)).unwrap();
                let shadow = run_script(&client, i as u64);
                shadows.push((name, shadow));
            }
            shadows
        }));
    }
    let mut all: Vec<(String, HashMap<u64, Vec<u8>>)> = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    assert_eq!(all.len(), FLEET);

    // fleet quiesced: re-verify a sample end-to-end (no cross-VM bleed)
    for (name, shadow) in all.iter().step_by(97) {
        let client = coord.client(name).unwrap();
        for (o, v) in shadow {
            assert_eq!(&client.read(*o, v.len()).unwrap(), v, "{name} voff {o}");
        }
    }

    // every shard owns a share of the fleet and did real work
    let shards = coord.shard_stats();
    assert_eq!(shards.iter().map(|s| s.vms).sum::<u64>(), FLEET as u64);
    for s in &shards {
        assert!(s.vms > 0, "shard {} owns no VMs (bad spread)", s.shard);
        assert!(s.served > 0, "shard {} served nothing", s.shard);
    }
    coord.shutdown();
}

/// The same deterministic scripts on a sharded pool and on a
/// single-executor pool must leave byte-identical disks and identical
/// service counters — the literal "bit-identical to sequential" check.
#[test]
fn sharded_execution_matches_single_executor() {
    const FLEET: usize = 64;
    let sharded = coordinator(2, 4);
    let single = coordinator(2, 1);
    for coord in [&sharded, &single] {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let coord = Arc::clone(coord);
            handles.push(std::thread::spawn(move || {
                for i in (t..FLEET).step_by(4) {
                    let name = format!("eq-{i:03}");
                    let client =
                        coord.launch_vm(&name, tiny_vm(&name, i as u64, 1)).unwrap();
                    run_script(&client, i as u64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
    for i in 0..FLEET {
        let name = format!("eq-{i:03}");
        let a = sharded.client(&name).unwrap();
        let b = single.client(&name).unwrap();
        for k in 0..14u64 {
            let (va, vb) =
                (a.read(k * CLUSTER, 512).unwrap(), b.read(k * CLUSTER, 512).unwrap());
            assert_eq!(va, vb, "{name} cluster {k} diverged from sequential");
        }
        let (sa, sb) =
            (sharded.vm_stats(&name).unwrap(), single.vm_stats(&name).unwrap());
        assert_eq!(
            (sa.reads, sa.writes, sa.bytes_read, sa.bytes_written),
            (sb.reads, sb.writes, sb.bytes_read, sb.bytes_written),
            "{name} service counters diverged"
        );
    }
    sharded.shutdown();
    single.shutdown();
}

/// Telemetry must be invisible to the data plane: the same deterministic
/// scripts on a fleet with tracing sampled at every VM — and a scraper
/// thread hammering the exporter throughout — leave byte-identical disks
/// and identical service counters to a telemetry-quiet fleet.
#[test]
fn telemetry_and_tracing_do_not_perturb_execution() {
    const FLEET: usize = 32;
    let plain = coordinator(2, 4);
    let clock = VirtClock::new();
    let set = (0..2)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let traced = Coordinator::new(
        Arc::new(NodeSet::new(set).unwrap()),
        clock,
        CoordinatorConfig { shards: 4, trace_sample: 1, ..Default::default() },
        RuntimeService::try_default(),
    );
    for (coord, scrape) in [(&plain, false), (&traced, true)] {
        let stop = Arc::new(AtomicBool::new(false));
        let scraper = scrape.then(|| {
            let coord = Arc::clone(coord);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut scrapes = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let text = coord.telemetry().render();
                    assert!(text.contains("# TYPE sqemu_shard_vms gauge"));
                    scrapes += 1;
                }
                scrapes
            })
        });
        let mut handles = Vec::new();
        for t in 0..4usize {
            let coord = Arc::clone(coord);
            handles.push(std::thread::spawn(move || {
                for i in (t..FLEET).step_by(4) {
                    let name = format!("tel-{i:03}");
                    let client =
                        coord.launch_vm(&name, tiny_vm(&name, i as u64, 1)).unwrap();
                    run_script(&client, i as u64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        if let Some(s) = scraper {
            assert!(s.join().unwrap() > 0, "the scraper thread never ran");
        }
    }
    for i in 0..FLEET {
        let name = format!("tel-{i:03}");
        let (a, b) = (plain.client(&name).unwrap(), traced.client(&name).unwrap());
        for k in 0..14u64 {
            assert_eq!(
                a.read(k * CLUSTER, 512).unwrap(),
                b.read(k * CLUSTER, 512).unwrap(),
                "{name} cluster {k} diverged with telemetry enabled"
            );
        }
        let (sa, sb) =
            (plain.vm_stats(&name).unwrap(), traced.vm_stats(&name).unwrap());
        assert_eq!(
            (sa.reads, sa.writes, sa.bytes_read, sa.bytes_written),
            (sb.reads, sb.writes, sb.bytes_read, sb.bytes_written),
            "{name} service counters diverged with telemetry enabled"
        );
    }
    // every VM was trace-sampled: real spans reached the shared ring
    assert!(traced.trace_ring().total() > 0, "no spans were recorded");
    plain.shutdown();
    traced.shutdown();
}

/// Async half of the client: many operations in flight on one VM,
/// completions reaped out of order, program order still governs the
/// bytes (read-your-writes through the ring).
#[test]
fn async_submissions_preserve_program_order() {
    let coord = coordinator(1, 2);
    let client = coord.launch_vm("vm-async", tiny_vm("vm-async", 2, 1)).unwrap();
    let w1 = client.submit_write(0, vec![0xAA; 512]).unwrap();
    let w2 = client.submit_write(0, vec![0xBB; 512]).unwrap();
    let r = client.submit_read(0, 512).unwrap();
    let f = client.submit_flush().unwrap();
    // reap deliberately out of order: the flush barrier first
    match client.complete(f).unwrap() {
        sqemu::coordinator::RingReply::Flush(res) => res.unwrap(),
        other => panic!("expected flush completion, got {other:?}"),
    }
    match client.complete(r).unwrap() {
        sqemu::coordinator::RingReply::Read(res) => {
            assert_eq!(res.unwrap(), vec![0xBB; 512], "read saw the older write");
        }
        other => panic!("expected read completion, got {other:?}"),
    }
    for tag in [w1, w2] {
        match client.complete(tag).unwrap() {
            sqemu::coordinator::RingReply::Write(res) => res.unwrap(),
            other => panic!("expected write completion, got {other:?}"),
        }
    }
    assert!(client.try_complete(r).unwrap().is_none(), "tag reaped once");
    coord.shutdown();
}

/// Fairness: a neighbour flooding its own ring must not starve another
/// VM on the same (single) shard — round-robin bursts bound what one VM
/// can hog per pass, so the quiet VM's sync reads all complete while the
/// flood is still in flight.
#[test]
fn flooding_neighbour_does_not_starve_the_quiet_vm() {
    let coord = coordinator(1, 1);
    let quiet = coord.launch_vm("vm-quiet", tiny_vm("vm-quiet", 4, 1)).unwrap();
    let noisy = coord.launch_vm("vm-noisy", tiny_vm("vm-noisy", 5, 1)).unwrap();
    quiet.write(0, vec![0x11; 512]).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let flood = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut tags = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                tags.push(noisy.submit_write(0, vec![0x22; 4096]).unwrap());
            }
            for t in tags {
                noisy.complete(t).unwrap();
            }
        })
    };
    // every sync read on the quiet VM completes while the flood runs;
    // starvation would hang here (and the harness would time out)
    for _ in 0..100 {
        assert_eq!(quiet.read(0, 512).unwrap(), vec![0x11; 512]);
    }
    stop.store(true, Ordering::Relaxed);
    flood.join().unwrap();
    let stats = coord.vm_stats("vm-quiet").unwrap();
    assert_eq!(stats.reads, 100);
    coord.shutdown();
}

/// Regression (satellite a): a paused block job used to make its worker
/// poll on a 2ms recv_timeout — ~150 spurious wakeups over 300ms. The
/// executor now parks and is woken by the resume doorbell; only the
/// 100ms backstop ticks while the job is paused.
#[test]
fn paused_job_parks_the_executor_instead_of_spinning() {
    let coord = coordinator(1, 1);
    let _client =
        coord.launch_vm("vm-paused", tiny_vm("vm-paused", 6, 3)).unwrap();
    let shared = coord
        .start_job("vm-paused", JobSpec::stream(0).paused())
        .unwrap();

    // let the executor settle into its parked state
    std::thread::sleep(std::time::Duration::from_millis(150));
    let w0: u64 = coord.shard_stats().iter().map(|s| s.wakeups).sum();
    std::thread::sleep(std::time::Duration::from_millis(300));
    let w1: u64 = coord.shard_stats().iter().map(|s| s.wakeups).sum();
    let spurious = w1 - w0;
    assert!(
        spurious < 15,
        "parked executor woke {spurious} times in 300ms (2ms-poll regression; \
         expected ~3 backstop ticks)"
    );

    // the doorbell ends the park: resume completes the job promptly
    coord.resume_job(&shared.id).unwrap();
    let status = coord.wait_job(&shared);
    assert_eq!(status.state, sqemu::blockjob::JobState::Completed, "{status:?}");
    coord.shutdown();
}
