//! On-disk format integration: create → populate → snapshot → reopen →
//! verify, over both real files and in-memory backends, with `qcheck`
//! after every mutating phase.

use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::qcheck;
use sqemu::qcow::snapshot;
use sqemu::qcow::Chain;
use sqemu::storage::backend::BackendRef;
use sqemu::storage::file::FileBackend;
use sqemu::storage::node::StorageNode;
use sqemu::util::rng::Rng;
use std::sync::Arc;

fn tmpdir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sqemu-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn image_survives_reopen_on_real_files() {
    let dir = tmpdir();
    let path = dir.join("disk.sq");
    let geom = Geometry::new(16, 64 << 20).unwrap();
    let mut written = Vec::new();
    {
        let backend: BackendRef = Arc::new(FileBackend::create(&path).unwrap());
        let img =
            Image::create("disk.sq", backend, geom, FEATURE_BFI, 0, None, DataMode::Real)
                .unwrap();
        let mut rng = Rng::new(1);
        for vc in [0u64, 7, 500, 1000] {
            let off = img.alloc_data_cluster().unwrap();
            let mut data = vec![0u8; 4096];
            rng.fill_bytes(&mut data);
            img.write_data(off, 0, &data).unwrap();
            img.set_l2_entry(vc, L2Entry::local(off, Some(0))).unwrap();
            written.push((vc, off, data));
        }
    }
    // reopen from the actual file on disk
    let backend: BackendRef = Arc::new(FileBackend::open(&path).unwrap());
    let img = Image::open("disk.sq", backend, DataMode::Real).unwrap();
    assert!(img.has_bfi());
    for (vc, off, data) in &written {
        let e = img.l2_entry(*vc).unwrap();
        assert_eq!(e.host_offset(), *off);
        let mut back = vec![0u8; data.len()];
        img.read_data(*off, 0, &mut back).unwrap();
        assert_eq!(&back, data);
    }
    let report = qcheck::check_image(&img).unwrap();
    assert!(report.is_clean(), "{:?}", report.errors);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn chain_lifecycle_with_qcheck_at_every_step() {
    let clock = VirtClock::new();
    let node = StorageNode::new("s", clock, CostModel::default());
    let b = node.create_file("img-0").unwrap();
    let geom = Geometry::new(16, 32 << 20).unwrap();
    let img =
        Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real).unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    let mut rng = Rng::new(2);
    let mut model: std::collections::HashMap<u64, Vec<u8>> = Default::default();

    for step in 0..6 {
        // write a few clusters into the active volume
        for _ in 0..8 {
            let vc = rng.below(geom.num_vclusters());
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            let mut data = vec![0u8; 128];
            rng.fill_bytes(&mut data);
            img.write_data(off, 0, &data).unwrap();
            img.set_l2_entry(vc, L2Entry::local(off, Some(img.chain_index())))
                .unwrap();
            model.insert(vc, data);
        }
        snapshot::snapshot_sqemu(&mut chain, &node, &format!("img-{}", step + 1))
            .unwrap();
        let report = qcheck::check_chain(&chain).unwrap();
        assert!(report.is_clean(), "step {step}: {:?}", report.errors);
    }

    // every model cluster resolves to its latest content via chain walk
    for (vc, data) in &model {
        let (bfi, off) = chain.resolve_walk(*vc).unwrap().expect("resolves");
        let mut back = vec![0u8; data.len()];
        chain.get(bfi).unwrap().read_data(off, 0, &mut back).unwrap();
        assert_eq!(&back, data, "vc={vc}");
    }
    // ... and the active volume's stamps agree with the walk
    for (vc, _) in &model {
        let stamp = chain.active().l2_entry(*vc).unwrap();
        let walk = chain.resolve_walk(*vc).unwrap().unwrap();
        assert_eq!(stamp.sqemu_view(chain.active().chain_index()), Some(walk));
    }
}

#[test]
fn reopen_chain_from_node() {
    let clock = VirtClock::new();
    let node = StorageNode::new("s", clock, CostModel::default());
    let b = node.create_file("img-0").unwrap();
    let geom = Geometry::new(16, 16 << 20).unwrap();
    let img =
        Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real).unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    for i in 0..4 {
        snapshot::snapshot_sqemu(&mut chain, &node, &format!("img-{}", i + 1)).unwrap();
    }
    drop(chain);
    let chain = Chain::open(&node, "img-4", DataMode::Real).unwrap();
    assert_eq!(chain.len(), 5);
    assert!(qcheck::check_chain(&chain).unwrap().is_clean());
}

#[test]
fn snapshot_disk_overhead_matches_eq2() {
    // Fig 19a / Eq. 2: an SQEMU snapshot of a fully indexed disk carries
    // the whole L2 metadata: disk_size/cluster_size * entry_size
    let clock = VirtClock::new();
    let node = StorageNode::new("s", clock, CostModel::default());
    let b = node.create_file("img-0").unwrap();
    let geom = Geometry::new(16, 64 << 20).unwrap();
    let img =
        Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real).unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    // populate every cluster ("worst case, the disk is full")
    for vc in 0..geom.num_vclusters() {
        let img = chain.active();
        let off = img.alloc_data_cluster().unwrap();
        img.set_l2_entry(vc, L2Entry::local(off, Some(0))).unwrap();
    }
    let before: u64 = chain.active().file_len();
    snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
    let s_sq = chain.active().file_len();
    snapshot::snapshot_vanilla(&mut chain, &node, "img-2").unwrap();
    let s_vq = chain.active().file_len();
    let eq2 = geom.num_vclusters() * 8;
    let overhead = s_sq - s_vq;
    assert!(
        overhead >= eq2 && overhead <= eq2 + 4 * geom.cluster_size(),
        "overhead={overhead} eq2={eq2}"
    );
    let _ = before;
}
