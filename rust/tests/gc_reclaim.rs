//! GC end-to-end invariants: streaming a chain to length 1 and running
//! GC returns the dropped files' capacity to the node (within 10% of the
//! single-file footprint); a base image shared by 8 chains survives
//! until the *last* chain streams; cancelling mid-sweep leaves a
//! consistent deferred-delete set (files are deleted atomically, never
//! half-collected); the leak audit catches files no chain reaches.

use sqemu::blockjob::{JobKind, JobRunner, JobShared, JobState, Step};
use sqemu::cache::CacheConfig;
use sqemu::chaingen::ChainSpec;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, NodeSet, VmConfig};
use sqemu::gc::{GcJob, GcRegistry};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::storage::store::FileStore;
use sqemu::vdisk::{Driver, DriverKind};
use std::sync::Arc;

fn launch_generated(coord: &Arc<Coordinator>, name: &str, chain_len: usize) {
    coord
        .launch_vm(
            name,
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(64, 1 << 20),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: 16 << 20,
                    chain_len,
                    populated: 0.5,
                    stamped: true,
                    data_mode: DataMode::Real,
                    prefix: name.into(),
                    seed: 0x6C0 ^ name.len() as u64,
                    ..Default::default()
                }),
            },
        )
        .unwrap();
}

#[test]
fn stream_100_deep_then_gc_reclaims_capacity() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    launch_generated(&coord, "vm-a", 100);
    let node = Arc::clone(&coord.nodes.nodes()[0]);
    let used_before = node.used_bytes();
    assert_eq!(coord.chain_files("vm-a").unwrap().len(), 100);

    let report = coord.stream_vm("vm-a", 0, 99).unwrap();
    assert_eq!(report.len_after, 1);
    // files are dropped from the chain but still on the node: condemned,
    // so pressure falls ahead of the physical sweep
    assert_eq!(coord.gc_registry().condemned_count(), 99);
    // nothing deleted yet (the merge even grows the target file)
    assert!(node.used_bytes() >= used_before);
    assert!(node.pressure_bytes() < node.used_bytes());

    let gc = coord.run_gc(0).unwrap();
    assert_eq!(gc.files_deleted, 99);
    assert_eq!(gc.remaining_condemned, 0);
    assert!(gc.reclaimed_bytes > 0);

    // within 10% of (here: exactly) the surviving single-file footprint
    let files = coord.chain_files("vm-a").unwrap();
    assert_eq!(files.len(), 1);
    let active_bytes = coord.nodes.open_file(&files[0]).unwrap().stored_bytes();
    let used = node.used_bytes();
    assert!(used >= active_bytes);
    assert!(
        used * 10 <= active_bytes * 11,
        "used {used} not within 10% of single-file footprint {active_bytes}"
    );

    // stats surfaced per VM and per node
    let s = coord.vm_stats("vm-a").unwrap();
    assert_eq!(s.reclaimed_bytes, gc.reclaimed_bytes);
    assert_eq!(s.gc_runs, 1);
    assert_eq!(node.reclaimed_bytes(), gc.reclaimed_bytes);
    assert_eq!(node.gc_deletes(), 99);
    assert_eq!(coord.gc_registry().gc_runs(), 1);

    // and the VM still serves its (collapsed) disk
    let client = coord.client("vm-a").unwrap();
    client.read(0, 4096).unwrap();
    coord.shutdown();
}

/// Build `n_chains` sqemu chains of `depth` snapshots each, all backing
/// onto one shared base image, and launch a VM on each.
fn shared_base_fleet(
    coord: &Arc<Coordinator>,
    n_chains: usize,
    depth: usize,
) -> Vec<String> {
    let nodes = Arc::clone(&coord.nodes);
    let b = nodes.create_file("base").unwrap();
    let base = Image::create(
        "base",
        b,
        Geometry::new(16, 8 << 20).unwrap(),
        FEATURE_BFI,
        0,
        None,
        DataMode::Real,
    )
    .unwrap();
    {
        // one cluster of real data in the shared base
        let off = base.alloc_data_cluster().unwrap();
        base.write_data(off, 0, &[0xBB; 64]).unwrap();
        base.set_l2_entry(0, L2Entry::local(off, Some(0))).unwrap();
    }
    drop(base);
    let mut vms = Vec::new();
    for k in 0..n_chains {
        let mut chain = Chain::open(nodes.as_ref(), "base", DataMode::Real).unwrap();
        for d in 1..=depth {
            snapshot::snapshot_sqemu(&mut chain, nodes.as_ref(), &format!("c{k}-{d}"))
                .unwrap();
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[(k * 16 + d) as u8; 64]).unwrap();
            img.set_l2_entry(d as u64, L2Entry::local(off, Some(img.chain_index())))
                .unwrap();
        }
        let vm = format!("vm-{k}");
        coord
            .launch_vm(
                &vm,
                VmConfig {
                    driver: DriverKind::Scalable,
                    cache: CacheConfig::new(64, 1 << 20),
                    chain: VmChain::Existing {
                        active_name: format!("c{k}-{depth}"),
                        data_mode: DataMode::Real,
                    },
                },
            )
            .unwrap();
        vms.push(vm);
    }
    vms
}

#[test]
fn shared_base_survives_until_the_last_chain_streams() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    let depth = 12usize;
    let vms = shared_base_fleet(&coord, 8, depth);
    assert_eq!(coord.gc_registry().refcount("base"), 8);

    for (k, vm) in vms.iter().enumerate() {
        let report = coord.stream_vm(vm, 0, depth as u16).unwrap();
        assert_eq!(report.len_after, 1);
        let gc = coord.run_gc(0).unwrap();
        assert!(gc.files_deleted >= depth as u64 - 1);
        // this chain's own intermediate files are gone...
        assert!(coord.nodes.open_file(&format!("c{k}-1")).is_err());
        if k + 1 < vms.len() {
            // ...but the shared base survives while any chain references it
            assert!(
                coord.nodes.open_file("base").is_ok(),
                "base deleted while {} chain(s) still reference it",
                vms.len() - k - 1
            );
            assert_eq!(coord.gc_registry().refcount("base"), vms.len() - k - 1);
            // an unstreamed chain still reads the base's cluster
            let probe = coord.client(&vms[k + 1]).unwrap();
            let buf = probe.read(0, 64).unwrap();
            assert_eq!(&buf[..8], &[0xBB; 8][..], "shared base data intact");
        } else {
            // the last reference is gone: base reclaimed
            assert!(
                coord.nodes.open_file("base").is_err(),
                "base must be reclaimed once no chain references it"
            );
        }
    }
    // fleet fully streamed: one file per chain remains
    let audit = coord.gc_audit();
    assert!(audit.is_clean(), "leaks: {:?}", audit.leaked);
    assert_eq!(audit.reachable, 8);
    coord.shutdown();
}

#[test]
fn cancel_mid_sweep_leaves_consistent_deferred_set() {
    let clock = VirtClock::new();
    let nodes = Arc::new(
        NodeSet::new(vec![StorageNode::new(
            "n0",
            clock.clone(),
            CostModel::default(),
        )])
        .unwrap(),
    );
    let reg = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
    for i in 0..6 {
        let f = nodes.create_file(&format!("f{i}")).unwrap();
        f.write_at(&[3u8; 2 << 10], 0).unwrap();
    }
    reg.sync_chain("c", (0..6).map(|i| format!("f{i}")).collect());
    reg.drop_chain("c");
    assert_eq!(reg.condemned_count(), 6);

    let mut d = sqemu::gc::scratch_driver(clock.clone(), CostModel::default()).unwrap();
    let shared = Arc::new(JobShared::new("gc-x", JobKind::Gc, 0));
    let fence = Arc::clone(d.fence());
    let job = Box::new(GcJob::new(Arc::clone(&reg)));
    // 2 files per increment: one step deletes f0, f1 then we cancel
    let mut r = JobRunner::new(job, Arc::clone(&shared), fence, 2, 1 << 20, clock.now());
    assert_eq!(r.step(&mut d, clock.now()), Step::Ran);
    shared.cancel();
    assert_eq!(r.step(&mut d, clock.now()), Step::Finished);
    assert_eq!(shared.status().state, JobState::Cancelled);

    // invariant: every file is either fully deleted (and no longer
    // condemned) or fully present (and still condemned) — no half states
    let mut present = 0;
    for i in 0..6 {
        let name = format!("f{i}");
        let exists = nodes.open_file(&name).is_ok();
        assert_eq!(
            exists,
            reg.is_condemned(&name),
            "file '{name}' in a half-collected state"
        );
        if exists {
            present += 1;
        }
    }
    assert_eq!(present, 4, "exactly one increment of deletions happened");

    // a later sweep finishes the job from the consistent set
    let shared2 = Arc::new(JobShared::new("gc-y", JobKind::Gc, 0));
    let fence2 = Arc::clone(d.fence());
    let job2 = Box::new(GcJob::new(Arc::clone(&reg)));
    let mut r2 = JobRunner::new(job2, Arc::clone(&shared2), fence2, 16, 1 << 20, clock.now());
    loop {
        match r2.step(&mut d, clock.now()) {
            Step::Finished => break,
            Step::Starved { ready_at } => {
                let now = clock.now();
                clock.advance(ready_at - now);
            }
            _ => {}
        }
    }
    assert_eq!(shared2.status().state, JobState::Completed);
    assert_eq!(reg.condemned_count(), 0);
    for i in 0..6 {
        assert!(nodes.open_file(&format!("f{i}")).is_err());
    }
}

#[test]
fn leak_audit_catches_orphaned_file() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    launch_generated(&coord, "vm-a", 5);
    let audit = coord.gc_audit();
    assert!(audit.is_clean(), "fresh fleet leaks: {:?}", audit.leaked);
    assert_eq!(audit.reachable, 5);

    // a file no chain references and GC was never told about
    let orphan = coord.nodes.create_file("orphaned-img").unwrap();
    orphan.write_at(&[7u8; 32 << 10], 0).unwrap();
    let audit = coord.gc_audit();
    assert!(!audit.is_clean());
    assert_eq!(audit.leaked.len(), 1);
    assert_eq!(audit.leaked[0].0, "orphaned-img");
    assert_eq!(audit.leaked_bytes(), 32 << 10);

    // condemned files are *not* leaks: they are scheduled work
    coord.stream_vm("vm-a", 0, 4).unwrap();
    let audit = coord.gc_audit();
    assert_eq!(audit.condemned.len(), 4);
    assert_eq!(audit.leaked.len(), 1, "orphan still the only leak");

    // GC sweeps the condemned set but never touches unknown files —
    // deleting a leak is an operator decision (the audit's output)
    coord.run_gc(0).unwrap();
    let audit = coord.gc_audit();
    assert!(audit.condemned.is_empty());
    assert_eq!(audit.leaked.len(), 1);
    coord.nodes.delete_file("orphaned-img").unwrap();
    assert!(coord.gc_audit().is_clean());
    coord.shutdown();
}

#[test]
fn decommission_condemns_unshared_files_and_gc_empties_the_node() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    launch_generated(&coord, "vm-a", 8);
    let node = Arc::clone(&coord.nodes.nodes()[0]);
    assert!(node.used_bytes() > 0);
    coord.decommission_vm("vm-a").unwrap();
    assert_eq!(coord.gc_registry().condemned_count(), 8);
    let gc = coord.run_gc(0).unwrap();
    assert_eq!(gc.files_deleted, 8);
    assert_eq!(node.used_bytes(), 0, "decommissioned chain fully reclaimed");
}
