//! HA control-plane suite: write-ahead StateStore recovery, lease-based
//! VM ownership and leader failover.
//!
//! The tentpole property test kills the leader at EVERY metadata-node
//! durable-event index (clean cuts and sector-torn cuts) while a
//! migration is in flight under guest I/O, then fails over to a standby
//! and asserts the contract: exactly one coordinator holds each lease,
//! recovery work is bounded by the active-lease count (never a fleet
//! scan), and no guest byte whose flush was acknowledged is lost.
//!
//! On failure, the failing (cut index, tear) tuple is written to
//! `$HA_REPRO_PATH` (default `ha_repro.txt`) so CI can attach the repro.

use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::control::StateStore;
use sqemu::coordinator::server::{CoordinatorConfig, VmChain};
use sqemu::coordinator::{Coordinator, NodeSet, VmConfig};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::image::DataMode;
use sqemu::storage::fault::{FaultInjector, SECTOR};
use sqemu::storage::node::StorageNode;
use sqemu::vdisk::DriverKind;
use std::sync::Arc;

/// Short virtual-clock lease TTL so takeover's wait-out is cheap.
const TTL: u64 = 5_000_000_000;

/// One fleet: data nodes in the coordinator's NodeSet plus a dedicated
/// metadata node (fault-injectable) carrying the control log.
struct Fleet {
    clock: Arc<VirtClock>,
    nodes: Arc<NodeSet>,
    store: Arc<StateStore>,
    meta_faults: Arc<FaultInjector>,
}

fn fleet(n_nodes: usize) -> Fleet {
    let clock = VirtClock::new();
    let data = (0..n_nodes)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let nodes = Arc::new(NodeSet::new(data).unwrap());
    let meta_faults = FaultInjector::new();
    let meta = StorageNode::with_fault_injection(
        "meta-0",
        clock.clone(),
        CostModel::default(),
        u64::MAX,
        Arc::clone(&meta_faults),
    );
    let store = StateStore::open(meta).unwrap();
    Fleet { clock, nodes, store, meta_faults }
}

fn coordinator(f: &Fleet, who: &str) -> Arc<Coordinator> {
    let c = Coordinator::new(
        Arc::clone(&f.nodes),
        Arc::clone(&f.clock),
        CoordinatorConfig { lease_ttl_ns: TTL, ..Default::default() },
        None,
    );
    c.attach_control(Arc::clone(&f.store), who).unwrap();
    c
}

fn vm_config(name: &str) -> VmConfig {
    VmConfig {
        driver: DriverKind::Scalable,
        cache: CacheConfig::new(16, 32 << 10),
        chain: VmChain::Existing {
            active_name: format!("{name}-1"),
            data_mode: DataMode::Real,
        },
    }
}

/// Generate a 2-deep Real chain for `name` pinned to `node`, then launch
/// it on `c`.
fn gen_and_launch(
    f: &Fleet,
    c: &Arc<Coordinator>,
    name: &str,
    node: &str,
    seed: u64,
) -> anyhow::Result<()> {
    let pin = f.nodes.pinned(node)?;
    generate(
        &pin,
        &ChainSpec {
            disk_size: 1 << 20,
            chain_len: 2,
            populated: 0.3,
            stamped: true,
            data_mode: DataMode::Real,
            prefix: name.to_string(),
            seed,
            ..Default::default()
        },
    )?;
    c.launch_vm(name, vm_config(name))?;
    Ok(())
}

fn data_list_ops(f: &Fleet) -> Vec<u64> {
    f.nodes.nodes().iter().map(|n| n.list_ops()).collect()
}

// ------------------------------------------------------ clean shutdown

/// Satellite: after `shutdown_clean` the next recovery trusts the log
/// outright — zero images checked, zero chains walked, zero data-node
/// listings — and the fleet relaunches and serves its data.
#[test]
fn clean_shutdown_recovery_skips_all_scans() {
    let f = fleet(2);
    let c1 = coordinator(&f, "c1");
    for v in 0..3u64 {
        gen_and_launch(&f, &c1, &format!("vm-{v}"), &format!("node-{}", v % 2), v)
            .unwrap();
        let client = c1.client(&format!("vm-{v}")).unwrap();
        client.write(4096, vec![0x42 + v as u8; 512]).unwrap();
        client.flush().unwrap();
    }
    c1.shutdown_clean().unwrap();
    assert!(f.store.status().clean_shutdown);
    assert_eq!(f.store.status().leases, 0, "clean stop released every lease");

    let lists = data_list_ops(&f);
    let c2 = coordinator(&f, "c2");
    let report = c2.recover();
    assert_eq!(report.images_checked, 0, "{report:?}");
    assert_eq!(report.chains_checked, 0, "{report:?}");
    assert_eq!(report.chains_repaired, 0, "{report:?}");
    assert!(report.unopenable.is_empty(), "{report:?}");
    assert_eq!(data_list_ops(&f), lists, "clean recovery listed a data node");

    let client = c2.launch_vm("vm-1", vm_config("vm-1")).unwrap();
    assert_eq!(client.read(4096, 512).unwrap(), vec![0x43; 512]);
    c2.shutdown();
}

// ------------------------------------------------- replay after crash

/// Replay recovery after a hard crash is bounded by the lease table:
/// only leased Real chains get an integrity walk, the placement index
/// is installed from the log, and no data node is ever listed.
#[test]
fn crash_replay_recovery_is_lease_bounded() {
    let f = fleet(2);
    let c1 = coordinator(&f, "c1");
    for v in 0..3u64 {
        gen_and_launch(&f, &c1, &format!("vm-{v}"), &format!("node-{}", v % 2), v)
            .unwrap();
        let client = c1.client(&format!("vm-{v}")).unwrap();
        client.write(8192, vec![0x70 + v as u8; 256]).unwrap();
        client.flush().unwrap();
    }
    c1.halt(); // crash: leases stay in the log, nothing is drained

    let lists = data_list_ops(&f);
    let c2 = coordinator(&f, "c2");
    let report = c2.recover();
    // the O(leases) bound: 3 leased VMs -> 3 chain walks, no image scan
    assert_eq!(report.images_checked, 0, "{report:?}");
    assert_eq!(report.chains_checked, 3, "{report:?}");
    assert!(report.unopenable.is_empty(), "{report:?}");
    assert_eq!(data_list_ops(&f), lists, "replay recovery listed a data node");

    // the dead leader's unexpired leases gate relaunch until they lapse
    let err = c2.launch_vm("vm-0", vm_config("vm-0")).unwrap_err();
    assert!(err.to_string().contains("leased"), "{err:#}");
    f.clock.advance(TTL);
    let client = c2.launch_vm("vm-0", vm_config("vm-0")).unwrap();
    assert_eq!(client.read(8192, 256).unwrap(), vec![0x70; 256]);
    c2.shutdown();
}

// ------------------------------------------------ lease orphan cleanup

/// Satellite: a lease without a VM record (the footprint of a launch
/// that died between lease acquire and the durable VM record) is
/// released during takeover once expired — orphan cleanup in O(leases).
#[test]
fn takeover_cleans_expired_orphan_leases() {
    let f = fleet(1);
    let c1 = coordinator(&f, "c1");
    // half-finished launch: the lease landed, the VM record never did
    f.store.acquire_lease(0, "ghost", "c1", TTL).unwrap();
    gen_and_launch(&f, &c1, "vm-0", "node-0", 7).unwrap();
    let client = c1.client("vm-0").unwrap();
    client.write(0, vec![0x99; 128]).unwrap();
    client.flush().unwrap();
    c1.halt();

    let c2 = coordinator(&f, "c2");
    let report = c2.takeover().unwrap();
    // only the real VM cost a chain walk; the orphan cost one release
    assert_eq!(report.chains_checked, 1, "{report:?}");
    assert!(report.unopenable.is_empty(), "{report:?}");
    assert!(f.store.lease_of("ghost").is_none(), "orphan lease survived");
    let l = f.store.lease_of("vm-0").unwrap();
    assert_eq!(l.holder, "c2");
    assert_eq!(c2.vm_names(), vec!["vm-0".to_string()]);
    assert_eq!(c2.client("vm-0").unwrap().read(0, 128).unwrap(), vec![0x99; 128]);
    c2.shutdown();
}

// ------------------------------------------------------- epoch fencing

/// A deposed leader's fenced writes bounce with an epoch-fence error:
/// it can neither stop nor launch VMs nor renew its leases once a new
/// coordinator has campaigned, and after failover exactly one
/// coordinator holds the lease.
#[test]
fn epoch_fencing_rejects_deposed_leader() {
    let f = fleet(1);
    let c1 = coordinator(&f, "c1");
    c1.campaign().unwrap();
    gen_and_launch(&f, &c1, "vm-0", "node-0", 11).unwrap();
    let client = c1.client("vm-0").unwrap();
    client.write(4096, vec![0xAB; 64]).unwrap();
    client.flush().unwrap();

    let c2 = coordinator(&f, "c2");
    c2.campaign().unwrap(); // c1 is now deposed

    let err = c1.stop_vm("vm-0").unwrap_err().to_string();
    assert!(err.contains("epoch fence"), "{err}");
    let err = c1.launch_vm("vm-x", vm_config("vm-0")).unwrap_err().to_string();
    assert!(err.contains("epoch fence"), "{err}");
    let err = c1.renew_leases().unwrap_err().to_string();
    assert!(err.contains("epoch fence"), "{err}");
    // the fence blocked the stop: vm-0 still runs and serves on c1
    assert_eq!(client.read(4096, 64).unwrap(), vec![0xAB; 64]);

    c1.halt();
    let report = c2.takeover().unwrap();
    assert_eq!(report.chains_checked, 1, "{report:?}");
    assert_eq!(f.store.leader(), "c2");
    assert_eq!(f.store.lease_of("vm-0").unwrap().holder, "c2");
    assert_eq!(c2.client("vm-0").unwrap().read(4096, 64).unwrap(), vec![0xAB; 64]);
    c2.shutdown();
}

// ------------------------------------------- renewal keeps ownership

/// Satellite: the leader's heartbeat renews every held lease with the
/// retrying backoff, pushing expiry forward on the virtual clock.
#[test]
fn lease_renewal_extends_ownership() {
    let f = fleet(1);
    let c1 = coordinator(&f, "c1");
    gen_and_launch(&f, &c1, "vm-0", "node-0", 13).unwrap();
    gen_and_launch(&f, &c1, "vm-1", "node-0", 14).unwrap();
    let before = f.store.lease_of("vm-0").unwrap().expires_ns;
    f.clock.advance(TTL / 2);
    assert_eq!(c1.renew_leases().unwrap(), 2);
    let after = f.store.lease_of("vm-0").unwrap().expires_ns;
    assert!(after > before, "renewal must push expiry: {before} -> {after}");
    c1.shutdown();
}

// -------------------------------------------- background capacity scan

/// Satellite: the rate-limited background capacity scan converges to the
/// same per-node logical-bytes counters as the synchronous
/// `refresh_capacity` walk, and its job record is closed in the log.
#[test]
fn background_capacity_scan_matches_sync_walk() {
    let f = fleet(2);
    let c1 = coordinator(&f, "c1");
    for v in 0..2u64 {
        gen_and_launch(&f, &c1, &format!("vm-{v}"), &format!("node-{v}"), 20 + v)
            .unwrap();
        let client = c1.client(&format!("vm-{v}")).unwrap();
        for i in 0..8u64 {
            client.write(i * 4096, vec![0x11 + v as u8; 4096]).unwrap();
        }
        client.flush().unwrap();
    }
    let shared = c1.start_capacity_scan(8 << 20).unwrap();
    let st = c1.wait_job(&shared);
    assert!(st.error.is_none(), "{:?}", st.error);
    let scanned: Vec<(String, u64)> = c1
        .nodes
        .node_stats()
        .into_iter()
        .map(|s| (s.name, s.logical_bytes))
        .collect();
    assert!(scanned.iter().any(|(_, l)| *l > 0), "scan found no bytes");
    // the background job's counters match a synchronous full walk
    for (name, logical, _) in c1.refresh_capacity() {
        let got = scanned.iter().find(|(n, _)| *n == name).unwrap().1;
        assert_eq!(got, logical, "node {name} diverged");
    }
    // reaped: the job closed out of the durable log too
    assert!(f.store.view().jobs.is_empty(), "scan job never closed");
    c1.shutdown();
}

// ------------------------------------------------- failover everywhere

/// Write the failing tuple where CI can pick it up, then panic with it.
fn fail_repro(cut: u64, tear: Option<u64>, msg: &str) -> ! {
    let path = std::env::var("HA_REPRO_PATH")
        .unwrap_or_else(|_| "ha_repro.txt".to_string());
    let note = format!(
        "ha-failover failure\ncut_at_event={cut} tear_keep_bytes={tear:?}\n{msg}\n"
    );
    let _ = std::fs::write(&path, &note);
    panic!("{note}");
}

/// The leader's run: launch two Real VMs under leases, flush-ack guest
/// writes (the durability oracle), start a live migration and keep
/// writing under it, then crash. Steps after the armed metadata cut
/// fail; everything acknowledged before stays in `durable`.
fn leader_scenario(f: &Fleet) -> Vec<(String, u64, Vec<u8>)> {
    let c1 = Coordinator::new(
        Arc::clone(&f.nodes),
        Arc::clone(&f.clock),
        CoordinatorConfig { lease_ttl_ns: TTL, ..Default::default() },
        None,
    );
    let mut durable = Vec::new();
    let _ = (|| -> anyhow::Result<()> {
        c1.attach_control(Arc::clone(&f.store), "c1")?;
        c1.campaign()?;
        for v in 0..2u64 {
            gen_and_launch(&f, &c1, &format!("vm-{v}"), &format!("node-{v}"), v)?;
        }
        for v in 0..2u64 {
            let name = format!("vm-{v}");
            let client = c1.client(&name)?;
            let mut pending = Vec::new();
            for i in 0..6u64 {
                let data = vec![(0x30 + v as u8) ^ i as u8; 512];
                client.write(i * 4096, data.clone())?;
                pending.push((name.clone(), i * 4096, data));
            }
            client.flush()?; // the ack commits these bytes forever
            durable.extend(pending);
        }
        // in-flight migration under guest load; never waited on — the
        // crash lands mid-copy and the journal must sort it out
        let _mig = c1.migrate_vm("vm-0", "node-1", 1 << 20)?;
        let client = c1.client("vm-0")?;
        let mut pending = Vec::new();
        for i in 0..4u64 {
            let data = vec![0xA0 ^ i as u8; 512];
            client.write((32 + i) * 4096, data.clone())?;
            pending.push(("vm-0".to_string(), (32 + i) * 4096, data));
        }
        client.flush()?;
        durable.extend(pending);
        Ok(())
    })();
    c1.halt(); // crash semantics: abandon everything, release nothing
    durable
}

/// Power the metadata node back on, fail over to a standby, and assert
/// the failover contract against the durability oracle.
fn verify_failover(f: &Fleet, durable: &[(String, u64, Vec<u8>)], cut: u64, tear: Option<u64>) {
    f.meta_faults.revive();
    let c2 = Coordinator::new(
        Arc::clone(&f.nodes),
        Arc::clone(&f.clock),
        CoordinatorConfig { lease_ttl_ns: TTL, ..Default::default() },
        None,
    );
    if let Err(e) = c2.attach_control(Arc::clone(&f.store), "c2") {
        fail_repro(cut, tear, &format!("attach_control: {e:#}"));
    }
    let report = match c2.takeover() {
        Ok(r) => r,
        Err(e) => fail_repro(cut, tear, &format!("takeover: {e:#}")),
    };
    // O(leases): at most the two launched VMs, never a fleet scan
    if report.images_checked != 0 || report.chains_checked > 2 {
        fail_repro(cut, tear, &format!("unbounded recovery: {report:?}"));
    }
    if !report.unopenable.is_empty() {
        fail_repro(cut, tear, &format!("adoption failures: {report:?}"));
    }
    // exactly one coordinator holds each surviving lease: the standby
    let v = f.store.view();
    for (vm, l) in &v.leases {
        if l.holder != "c2" {
            fail_repro(cut, tear, &format!("lease '{vm}' held by '{}'", l.holder));
        }
    }
    if f.store.leader() != "c2" {
        fail_repro(cut, tear, &format!("leader is '{}'", f.store.leader()));
    }
    // the in-flight migration is resolved, not left dangling
    if !v.migrations.is_empty() {
        fail_repro(cut, tear, &format!("dangling migrations: {:?}", v.migrations));
    }
    // no acknowledged-flushed guest byte is lost
    let adopted = c2.vm_names();
    for (vm, off, want) in durable {
        if !adopted.contains(vm) {
            fail_repro(cut, tear, &format!("acked vm '{vm}' not re-adopted"));
        }
        let client = match c2.client(vm) {
            Ok(c) => c,
            Err(e) => fail_repro(cut, tear, &format!("client '{vm}': {e:#}")),
        };
        match client.read(*off, want.len()) {
            Ok(got) if got == *want => {}
            Ok(got) => fail_repro(
                cut,
                tear,
                &format!(
                    "durable bytes lost: vm '{vm}' off {off}: got {:#x?}.., \
                     want {:#x?}..",
                    got[0], want[0]
                ),
            ),
            Err(e) => {
                fail_repro(cut, tear, &format!("read '{vm}' off {off}: {e:#}"))
            }
        }
    }
    // job ids never repeat across the failover: the next id must clear
    // the durable sequence high-water mark
    match c2.start_capacity_scan(64 << 20) {
        Ok(shared) => {
            let seq: u64 = shared
                .id
                .strip_prefix("job-")
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            if seq <= v.max_job_seq {
                fail_repro(
                    cut,
                    tear,
                    &format!("job id '{}' reuses seq <= {}", shared.id, v.max_job_seq),
                );
            }
            let st = c2.wait_job(&shared);
            if let Some(e) = st.error {
                fail_repro(cut, tear, &format!("post-failover scan: {e}"));
            }
        }
        Err(e) => fail_repro(cut, tear, &format!("post-failover job: {e:#}")),
    }
    c2.shutdown();
}

/// The tentpole property: kill the leader at EVERY metadata durable-event
/// boundary (clean and sector-torn cuts) during an active migration
/// under guest I/O; the standby takes over with lease-bounded work and
/// the durability contract holds.
#[test]
fn failover_at_every_durable_event_boundary() {
    // fault-free pass: bounds the cut range and checks the oracle.
    // `arm(k, ..)` counts k from its call point, after `fleet()` has
    // already opened the store — so the sweep range is measured from
    // the same post-open baseline.
    let f = fleet(2);
    let base = f.meta_faults.events();
    let durable = leader_scenario(&f);
    let n = f.meta_faults.events() - base;
    assert!(!durable.is_empty(), "scenario acknowledged nothing");
    verify_failover(&f, &durable, u64::MAX, None);
    assert!(n > 30, "scenario too small to be interesting: {n} events");

    let step = if n > 150 { 3 } else { 1 };
    let mut k = 0u64;
    while k < n {
        // alternate clean cuts and sector-torn cuts across the sweep
        let tear = if k % 2 == 1 { Some(SECTOR * (k % 8)) } else { None };
        let f = fleet(2);
        f.meta_faults.arm(k, tear);
        let durable = leader_scenario(&f);
        verify_failover(&f, &durable, k, tear);
        k += step;
    }
}
