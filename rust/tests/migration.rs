//! Migration under fire: the live chain-migration subsystem's
//! acceptance suite.
//!
//! * Property: mirror + random concurrent guest writes ≡ a non-migrated
//!   control chain bit-for-bit after switchover (100-deep chain).
//! * Crash-cut sweep: power-cut a migration at EVERY durable event
//!   (whole-node fault injection), then `Coordinator::recover()` must
//!   land on exactly ONE authoritative copy of every file with zero
//!   leaks (`gc::audit` clean).
//! * Coordinator e2e: capacity reservation visible during the copy,
//!   released after; sources GC-reclaimed; reads served throughout.
//! * Satellites: post-crash placement-index rebuild (pre-fix failing),
//!   snapshot chain locality, rebalancer convergence under 1.5x.

use sqemu::blockjob::{JobKind, JobRunner, JobShared, JobState, Step};
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::coordinator::placement::NodeSet;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, CoordinatorConfig, VmConfig};
use sqemu::gc::GcRegistry;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::migrate::{MirrorJob, JOURNAL_PREFIX};
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{qcheck, snapshot, Chain};
use sqemu::storage::fault::FaultInjector;
use sqemu::storage::node::StorageNode;
use sqemu::storage::store::FileStore;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::{Driver, DriverKind};
use std::sync::Arc;

const CLUSTER_BITS: u32 = 12; // 4 KiB clusters
const CS: u64 = 1 << CLUSTER_BITS;
const VCLUSTERS: u64 = 64;
const DISK: u64 = VCLUSTERS * CS;

fn two_nodes(clock: &Arc<VirtClock>) -> Arc<NodeSet> {
    Arc::new(
        NodeSet::new(vec![
            StorageNode::new("node-0", clock.clone(), CostModel::default()),
            StorageNode::new("node-1", clock.clone(), CostModel::default()),
        ])
        .unwrap(),
    )
}

/// Build a `depth`-deep stamped chain named `{prefix}-0..` through
/// `store`, one distinct populated cluster per layer (cluster `i %
/// VCLUSTERS` carries byte `i+1`).
fn build_chain(store: &dyn FileStore, prefix: &str, depth: usize) -> Chain {
    let b = store.create_file(&format!("{prefix}-0")).unwrap();
    let img = Image::create(
        &format!("{prefix}-0"),
        b,
        Geometry::new(CLUSTER_BITS, DISK).unwrap(),
        FEATURE_BFI,
        0,
        None,
        DataMode::Real,
    )
    .unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    for i in 0..depth {
        let img = chain.active();
        let off = img.alloc_data_cluster().unwrap();
        img.write_data(off, 0, &[(i % 250) as u8 + 1; 256]).unwrap();
        img.set_l2_entry(
            (i as u64) % VCLUSTERS,
            L2Entry::local(off, Some(img.chain_index())),
        )
        .unwrap();
        snapshot::snapshot_sqemu(&mut chain, store, &format!("{prefix}-{}", i + 1))
            .unwrap();
    }
    chain
}

fn driver_over(chain: Chain, clock: &Arc<VirtClock>) -> ScalableDriver {
    ScalableDriver::new(
        chain,
        CacheConfig::new(16, 32 << 10),
        Arc::clone(clock),
        CostModel::default(),
        MemoryAccountant::new(),
    )
}

/// One random guest write, applied identically to both drivers.
fn twin_write(
    a: &mut ScalableDriver,
    b: &mut ScalableDriver,
    rng: &mut Rng,
    op: u64,
) {
    let vc = rng.below(VCLUSTERS);
    let off = rng.below(CS - 600);
    let len = (rng.below(512) + 1) as usize;
    let val = (op as u8 ^ vc as u8).wrapping_mul(41).wrapping_add(3);
    let data = vec![val; len];
    a.write(vc * CS + off, &data).unwrap();
    b.write(vc * CS + off, &data).unwrap();
}

/// Tentpole property: a 100-deep chain migrates node-to-node while the
/// guest writes; post-switchover reads are bit-identical to a
/// non-migrated control that saw the same writes, the sources become
/// condemned replicas, a sweep empties the donor, and the audit is
/// clean throughout.
#[test]
fn mirror_under_guest_writes_is_bit_identical() {
    const DEPTH: usize = 100;
    let clock = VirtClock::new();
    let nodes = two_nodes(&clock);
    let store = nodes.pinned("node-0").unwrap();
    let chain = build_chain(&store, "m", DEPTH);
    let files = chain.file_names();
    let gc = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
    gc.sync_chain("vm", files.clone());
    let mut mig = driver_over(chain, &clock);

    // independent control fleet, identical content
    let ctl_clock = VirtClock::new();
    let ctl_node = StorageNode::new("ctl", ctl_clock.clone(), CostModel::default());
    let mut ctl = driver_over(build_chain(&*ctl_node, "m", DEPTH), &ctl_clock);

    mig.flush().unwrap();
    let fence = Arc::clone(mig.fence());
    let shared = Arc::new(JobShared::new("mig-1", JobKind::Mirror, 0));
    let job = Box::new(
        MirrorJob::new(mig.chain(), Arc::clone(&nodes), Arc::clone(&gc), "node-1", "vm")
            .unwrap(),
    );
    let mut runner = JobRunner::new(job, Arc::clone(&shared), fence, 8, 8 * CS, clock.now());

    let mut rng = Rng::new(0xF16_23);
    let mut op = 0u64;
    loop {
        match runner.step(&mut mig, clock.now()) {
            Step::Finished => break,
            Step::Starved { ready_at } => {
                let now = clock.now();
                clock.advance(ready_at - now);
            }
            _ => {}
        }
        // the guest keeps writing (both twins) every few increments
        if rng.chance(0.4) {
            twin_write(&mut mig, &mut ctl, &mut rng, op);
            op += 1;
        }
    }
    let st = shared.status();
    assert_eq!(st.state, JobState::Completed, "error: {:?}", st.error);
    assert!(op > 10, "the workload actually interleaved writes: {op}");

    // every chain file now resolves to the target node
    for f in &files {
        assert_eq!(nodes.locate(f).unwrap(), "node-1", "{f} not flipped");
    }
    // bit-identical to the control, cluster by cluster
    let mut a = vec![0u8; CS as usize];
    let mut b = vec![0u8; CS as usize];
    for vc in 0..VCLUSTERS {
        mig.read(vc * CS, &mut a).unwrap();
        ctl.read(vc * CS, &mut b).unwrap();
        assert_eq!(a, b, "cluster {vc} differs after migration");
    }
    assert!(qcheck::check_chain(mig.chain()).unwrap().is_clean());

    // sources are condemned replicas (never double-referenced), the
    // audit is clean before AND after the sweep, and the sweep empties
    // the donor node
    for f in &files {
        assert!(gc.is_replica_condemned("node-0", f), "{f} not condemned");
    }
    let report = sqemu::gc::audit(nodes.as_ref(), &gc);
    assert!(report.is_clean(), "pre-sweep audit: {:?}", report.leaked);
    let mut swept = 0;
    while gc.sweep_one().is_some() {
        swept += 1;
    }
    assert_eq!(swept, files.len());
    let n0 = nodes.node_named("node-0").unwrap();
    assert!(n0.file_names().is_empty(), "donor not empty: {:?}", n0.file_names());
    assert_eq!(sqemu::migrate::cleanup_journals(nodes.as_ref()), 1);
    let report = sqemu::gc::audit(nodes.as_ref(), &gc);
    assert!(report.is_clean(), "post-sweep audit: {:?}", report.leaked);
}

/// A fault-injected two-node fleet sharing one power supply.
fn faulty_nodes(
    clock: &Arc<VirtClock>,
    injector: &Arc<FaultInjector>,
) -> (Arc<StorageNode>, Arc<StorageNode>, Arc<NodeSet>) {
    let a = StorageNode::with_fault_injection(
        "node-0",
        clock.clone(),
        CostModel::default(),
        u64::MAX,
        Arc::clone(injector),
    );
    let b = StorageNode::with_fault_injection(
        "node-1",
        clock.clone(),
        CostModel::default(),
        u64::MAX,
        Arc::clone(injector),
    );
    let ns =
        Arc::new(NodeSet::new(vec![Arc::clone(&a), Arc::clone(&b)]).unwrap());
    (a, b, ns)
}

const CRASH_DEPTH: usize = 6;

/// Deterministic fixture: a CRASH_DEPTH chain on node-0, layer `i`
/// populating vcluster `8 + i` (guest writes during the migration stay
/// in vclusters 0..8, so clusters 8.. are a stable oracle).
fn crash_fixture(nodes: &Arc<NodeSet>) -> Chain {
    let store = nodes.pinned("node-0").unwrap();
    let b = store.create_file("c-0").unwrap();
    let img = Image::create(
        "c-0",
        b,
        Geometry::new(CLUSTER_BITS, DISK).unwrap(),
        FEATURE_BFI,
        0,
        None,
        DataMode::Real,
    )
    .unwrap();
    let mut chain = Chain::new(Arc::new(img)).unwrap();
    for i in 0..CRASH_DEPTH {
        let img = chain.active();
        let off = img.alloc_data_cluster().unwrap();
        img.write_data(off, 0, &[i as u8 + 1; 128]).unwrap();
        img.set_l2_entry(8 + i as u64, L2Entry::local(off, Some(img.chain_index())))
            .unwrap();
        snapshot::snapshot_sqemu(&mut chain, &store, &format!("c-{}", i + 1)).unwrap();
    }
    for img in chain.images() {
        img.flush().unwrap();
    }
    chain
}

/// Run the migration workload (mirror + interleaved guest writes in
/// vclusters 0..8) until it completes or the power cut kills it.
fn run_crash_migration(clock: &Arc<VirtClock>, nodes: &Arc<NodeSet>, chain: Chain) {
    let gc = Arc::new(GcRegistry::new(Arc::clone(nodes)));
    gc.sync_chain("vm", chain.file_names());
    let mut d = driver_over(chain, clock);
    let result = (|| -> anyhow::Result<()> {
        d.flush()?;
        let fence = Arc::clone(d.fence());
        let shared = Arc::new(JobShared::new("mig-c", JobKind::Mirror, 0));
        let job = Box::new(MirrorJob::new(
            d.chain(),
            Arc::clone(nodes),
            Arc::clone(&gc),
            "node-1",
            "vm",
        )?);
        let mut runner =
            JobRunner::new(job, Arc::clone(&shared), fence, 4, 4 * CS, clock.now());
        let mut rng = Rng::new(0xC0_FFEE);
        let mut op = 0u64;
        loop {
            match runner.step(&mut d, clock.now()) {
                Step::Finished => break,
                Step::Starved { ready_at } => {
                    let now = clock.now();
                    clock.advance(ready_at - now);
                }
                _ => {}
            }
            if rng.chance(0.5) {
                let vc = rng.below(8);
                let val = 0xA0u8 ^ op as u8;
                d.write(vc * CS, &[val; 64])?;
                op += 1;
            }
        }
        let st = shared.status();
        if let Some(e) = st.error {
            anyhow::bail!("job failed: {e}");
        }
        Ok(())
    })();
    // a power cut surfaces as an error somewhere in the loop — fine,
    // recovery is the subject under test
    let _ = result;
}

fn fail_crash_repro(cut: u64, msg: &str) -> ! {
    let path = std::env::var("CRASH_REPRO_PATH")
        .unwrap_or_else(|_| "crash_repro.txt".to_string());
    let note = format!(
        "migration crash-recovery failure\ncut_at_event={cut}\n{msg}\n(test: \
         tests/migration.rs::migration_crash_cut_sweep)\n"
    );
    let _ = std::fs::write(&path, &note);
    panic!("{note}");
}

/// Crash-cut sweep: power-cut the migration at EVERY durable event.
/// Recovery must land on exactly one authoritative copy of every chain
/// file, reopen a clean chain with the stable oracle intact, and audit
/// with zero leaks.
#[test]
fn migration_crash_cut_sweep() {
    // fault-free pass bounds the cut range
    let injector = FaultInjector::new();
    let clock = VirtClock::new();
    let (_a, _b, nodes) = faulty_nodes(&clock, &injector);
    let chain = crash_fixture(&nodes);
    let e0 = injector.events();
    run_crash_migration(&clock, &nodes, chain);
    let n = injector.events() - e0;
    assert!(n > 40, "migration too small to be interesting: {n} events");

    // cover every phase of the migration without an unbounded runtime
    let step = (n / 80).max(1);
    let mut k = 0u64;
    while k < n {
        let injector = FaultInjector::new();
        let clock = VirtClock::new();
        let (_a, _b, nodes) = faulty_nodes(&clock, &injector);
        let chain = crash_fixture(&nodes);
        injector.arm(k, None);
        run_crash_migration(&clock, &nodes, chain);
        injector.revive();
        verify_crash_recovery(&clock, &nodes, k);
        k += step;
    }
}

fn verify_crash_recovery(clock: &Arc<VirtClock>, nodes: &Arc<NodeSet>, cut: u64) {
    // "reboot": a fresh coordinator over the same durable nodes
    let ns2 = Arc::new(
        NodeSet::new(nodes.nodes().to_vec()).unwrap(),
    );
    let coord = Coordinator::new(
        Arc::clone(&ns2),
        Arc::clone(clock),
        CoordinatorConfig::default(),
        None,
    );
    let report = coord.recover();
    if !report.duplicate_files.is_empty() {
        fail_crash_repro(
            cut,
            &format!("duplicate files after recovery: {:?}", report.duplicate_files),
        );
    }
    // exactly one authoritative copy of every file, no journals left
    let mut seen = std::collections::HashMap::new();
    for node in ns2.nodes() {
        for f in node.file_names() {
            if f.starts_with(JOURNAL_PREFIX) {
                fail_crash_repro(cut, &format!("journal '{f}' survived recovery"));
            }
            *seen.entry(f).or_insert(0u32) += 1;
        }
    }
    for (f, count) in &seen {
        if *count != 1 {
            fail_crash_repro(cut, &format!("file '{f}' on {count} nodes"));
        }
    }
    // the head must reopen clean (recover repaired it) with the stable
    // oracle intact
    let head = format!("c-{CRASH_DEPTH}");
    let chain = match Chain::open(ns2.as_ref(), &head, DataMode::Real) {
        Ok(c) => c,
        Err(e) => fail_crash_repro(cut, &format!("reopen failed: {e:#}")),
    };
    match qcheck::check_chain(&chain) {
        Ok(r) if r.is_clean() => {}
        Ok(r) => fail_crash_repro(cut, &format!("chain dirty: {:?}", r.errors)),
        Err(e) => fail_crash_repro(cut, &format!("qcheck failed: {e:#}")),
    }
    for i in 0..CRASH_DEPTH as u64 {
        let resolved = chain.resolve_walk(8 + i).unwrap_or(None);
        let Some((bfi, off)) = resolved else {
            fail_crash_repro(cut, &format!("oracle cluster {} unresolved", 8 + i));
        };
        let mut buf = [0u8; 16];
        if let Err(e) = chain.get(bfi).unwrap().read_data(off, 0, &mut buf) {
            fail_crash_repro(cut, &format!("oracle read failed: {e:#}"));
        }
        if buf != [i as u8 + 1; 16] {
            fail_crash_repro(
                cut,
                &format!("oracle cluster {} lost: {:?}", 8 + i, &buf[..4]),
            );
        }
    }
    // zero leaks: everything on the nodes is reachable from the chain
    let gc = Arc::new(GcRegistry::new(Arc::clone(&ns2)));
    gc.sync_chain("vm", chain.file_names());
    let audit = sqemu::gc::audit(ns2.as_ref(), &gc);
    if !audit.is_clean() {
        fail_crash_repro(
            cut,
            &format!("audit: leaked {:?} errors {:?}", audit.leaked, audit.errors),
        );
    }
    drop(coord);
}

/// Coordinator e2e: the recipient's pressure includes the capacity
/// reservation during the copy and releases it after; cancel rolls the
/// target back; a completed migration moves every file, keeps serving
/// reads, and GC reclaims the sources.
#[test]
fn coordinator_migrate_reserves_serves_and_reclaims() {
    let clock = VirtClock::new();
    let nodes = two_nodes(&clock);
    let cfg = CoordinatorConfig {
        job_increment_clusters: 4,
        ..Default::default()
    };
    let coord = Coordinator::new(Arc::clone(&nodes), clock, cfg, None);
    coord
        .launch_vm(
            "vm",
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(64, 1 << 20),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: 1 << 20,
                    chain_len: 8,
                    populated: 0.5,
                    stamped: true,
                    data_mode: DataMode::Real,
                    prefix: "mv".into(),
                    seed: 0x5EED,
                    ..Default::default()
                }),
            },
        )
        .unwrap();
    let client = coord.client("vm").unwrap();
    let before = client.read(0, 4096).unwrap();
    let files = coord.chain_files("vm").unwrap();
    let target = nodes.node_named("node-1").unwrap();

    // chain generation scatters across both nodes: migrating to node-1
    // moves only the node-0 residents
    let moved: Vec<String> = files
        .iter()
        .filter(|f| nodes.locate(f).as_deref() == Some("node-0"))
        .cloned()
        .collect();
    assert!(!moved.is_empty(), "nothing on node-0 to move: {files:?}");

    // 1. a crawling migration exposes the reservation, then cancel
    //    rolls the partial copies back
    let shared = coord.migrate_vm("vm", "node-1", 512).unwrap();
    assert!(
        target.reserved_bytes() > 0 || shared.state().is_terminal(),
        "reservation not visible during the copy"
    );
    let stats = nodes.node_stats();
    assert_eq!(stats[1].reserved_bytes, target.reserved_bytes());
    coord.cancel_job(&shared.id).unwrap();
    let st = coord.wait_job(&shared);
    assert_eq!(st.state, JobState::Cancelled, "error: {:?}", st.error);
    // barrier: the worker tears the cancelled mirror down (deleting the
    // partial target copies) before serving the next request
    client.flush().unwrap();
    assert_eq!(target.reserved_bytes(), 0, "reservation released on cancel");
    for f in &moved {
        assert_eq!(
            nodes.locate(f).as_deref(),
            Some("node-0"),
            "{f} flipped by a cancelled migration"
        );
        assert!(
            target.open_file(f).is_err(),
            "partial copy of {f} survived the cancel"
        );
    }
    assert!(
        target.open_file(&format!("{JOURNAL_PREFIX}vm")).is_err(),
        "journal survived the cancel"
    );

    // 2. the real move, full speed, guest reads served meanwhile
    let shared = coord.migrate_vm("vm", "node-1", 0).unwrap();
    while !shared.state().is_terminal() {
        assert_eq!(client.read(0, 4096).unwrap(), before, "read during copy");
    }
    let st = coord.wait_job(&shared);
    assert_eq!(st.state, JobState::Completed, "error: {:?}", st.error);
    assert_eq!(target.reserved_bytes(), 0, "reservation released on completion");
    for f in &files {
        assert_eq!(nodes.locate(f).as_deref(), Some("node-1"), "{f} not moved");
    }
    assert_eq!(client.read(0, 4096).unwrap(), before, "read after switchover");

    // 3. GC reclaims the superseded sources and the audit is clean
    let gc_report = coord.run_gc(0).unwrap();
    assert_eq!(gc_report.files_deleted, moved.len() as u64);
    assert!(gc_report.journals_cleaned >= 1);
    let n0 = nodes.node_named("node-0").unwrap();
    assert!(n0.file_names().is_empty(), "{:?}", n0.file_names());
    let audit = coord.gc_audit();
    assert!(audit.is_clean(), "{:?}", audit.leaked);
    let snap = coord.vm_stats("vm").unwrap();
    assert_eq!(snap.jobs_started, 2);
    assert_eq!(snap.jobs_completed, 1);
    assert_eq!(snap.jobs_cancelled, 1);
    coord.shutdown();
}

/// Satellite bugfix (pre-fix failing): the name→node index is rebuilt
/// from the nodes' durable file lists on recover(), so a freshly booted
/// coordinator can locate and reopen pre-existing chains.
#[test]
fn post_crash_index_rebuild_locates_chains() {
    let clock = VirtClock::new();
    let a = StorageNode::new("node-0", clock.clone(), CostModel::default());
    let b = StorageNode::new("node-1", clock.clone(), CostModel::default());
    {
        let ns1 =
            Arc::new(NodeSet::new(vec![Arc::clone(&a), Arc::clone(&b)]).unwrap());
        let store = ns1.pinned("node-0").unwrap();
        build_chain(&store, "x", 3);
    }
    // "crash": only the nodes (durable bytes) survive
    let ns2 = Arc::new(NodeSet::new(vec![a, b]).unwrap());
    let coord = Coordinator::new(
        Arc::clone(&ns2),
        clock,
        CoordinatorConfig::default(),
        None,
    );
    // the pre-fix behavior: an empty index that cannot locate anything
    assert!(ns2.locate("x-0").is_none(), "index unexpectedly populated");
    assert!(Chain::open(ns2.as_ref(), "x-3", DataMode::Real).is_err());

    let report = coord.recover();
    assert!(report.duplicate_files.is_empty(), "{report:?}");
    assert_eq!(ns2.locate("x-0").as_deref(), Some("node-0"));
    let chain = Chain::open(ns2.as_ref(), "x-3", DataMode::Real).unwrap();
    assert_eq!(chain.len(), 4);
    // and a VM can launch over the recovered namespace
    let client = coord
        .launch_vm(
            "vm",
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(16, 32 << 10),
                chain: VmChain::Existing {
                    active_name: "x-3".to_string(),
                    data_mode: DataMode::Real,
                },
            },
        )
        .unwrap();
    let got = client.read(0, 256).unwrap();
    assert_eq!(got, vec![1u8; 256], "layer-0 data served after recovery");
    coord.shutdown();
}

/// Satellite: chain-locality placement — a 10-snapshot chain stays on
/// one node instead of scattering file-by-file.
#[test]
fn snapshot_chain_stays_colocated() {
    let coord = Coordinator::with_fresh_nodes(3).unwrap();
    coord
        .launch_vm(
            "vm",
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(16, 32 << 10),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: 1 << 20,
                    chain_len: 1,
                    populated: 0.25,
                    stamped: true,
                    data_mode: DataMode::Real,
                    prefix: "loc".into(),
                    seed: 7,
                    ..Default::default()
                }),
            },
        )
        .unwrap();
    for i in 1..=10 {
        coord.snapshot_vm("vm", &format!("loc-{i}")).unwrap();
    }
    let files = coord.chain_files("vm").unwrap();
    assert_eq!(files.len(), 11);
    let homes: std::collections::HashSet<String> = files
        .iter()
        .map(|f| coord.nodes.locate(f).unwrap())
        .collect();
    assert_eq!(homes.len(), 1, "chain scattered across {homes:?}");
    coord.shutdown();
}

/// Satellite: the rebalancer brings an 8-chain skewed fleet's max/min
/// pressure ratio under 1.5x, sources are reclaimed, audit clean.
#[test]
fn rebalance_converges_skewed_fleet() {
    let coord = Coordinator::with_fresh_nodes(2).unwrap();
    for v in 0..8usize {
        let pin = if v == 7 { "node-1" } else { "node-0" };
        let store = coord.nodes.pinned(pin).unwrap();
        let name = format!("vm-{v}");
        generate(
            &store,
            &ChainSpec {
                disk_size: 8 << 20,
                chain_len: 6,
                populated: 0.3,
                stamped: true,
                data_mode: DataMode::Synthetic,
                prefix: name.clone(),
                seed: 0xBA1 ^ v as u64,
                ..Default::default()
            },
        )
        .unwrap();
        coord
            .launch_vm(
                &name,
                VmConfig {
                    driver: DriverKind::Scalable,
                    cache: CacheConfig::new(64, 1 << 20),
                    chain: VmChain::Existing {
                        active_name: format!("{name}-5"),
                        data_mode: DataMode::Synthetic,
                    },
                },
            )
            .unwrap();
    }
    let pressures: Vec<u64> = coord
        .nodes
        .nodes()
        .iter()
        .map(|n| n.pressure_bytes())
        .collect();
    let before = sqemu::migrate::rebalance::pressure_ratio(&pressures);
    assert!(before > 3.0, "fleet not skewed enough: {before}");

    // dry run plans but moves nothing
    let dry = coord.rebalance(1.5, 0, true).unwrap();
    assert!(!dry.plan.moves.is_empty());
    assert_eq!(dry.executed, 0);
    assert!(dry.final_ratio > 3.0);

    let report = coord.rebalance(1.5, 0, false).unwrap();
    assert!(report.executed >= 2, "{report:?}");
    assert!(
        report.final_ratio <= 1.5,
        "fleet still skewed: {:.2} ({report:?})",
        report.final_ratio
    );
    coord.run_gc(0).unwrap();
    let audit = coord.gc_audit();
    assert!(audit.is_clean(), "{:?}", audit.leaked);
    coord.shutdown();
}
