//! Property-based invariants over the whole substrate (DESIGN.md §6):
//! read-your-writes across snapshots, COW never mutates backing files,
//! stamps always agree with the chain walk, streaming preserves content,
//! LRU respects its budget.

use sqemu::cache::{CacheConfig, SliceCache};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{qcheck, snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::util::prop::forall;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::Driver;
use std::collections::HashMap;
use std::sync::Arc;

const CS: u64 = 64 << 10;
const VCLUSTERS: u64 = 40;

fn fresh_chain(node: &StorageNode) -> Chain {
    let geom = Geometry::new(16, VCLUSTERS * CS).unwrap();
    let b = node.create_file("img-0").unwrap();
    let img = Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real).unwrap();
    Chain::new(Arc::new(img)).unwrap()
}

#[test]
fn read_your_writes_across_random_snapshot_points() {
    forall(0xA11CE, 10, |rng| {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let chain = fresh_chain(&node);
        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::new(16, 64 << 10),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let mut model: HashMap<u64, u8> = HashMap::new();
        let mut snap_count = 0;
        for step in 0..60 {
            if rng.chance(0.15) && snap_count < 6 {
                // snapshot mid-stream: flush, snapshot, reopen driver
                d.flush().unwrap();
                let mut chain =
                    Chain::open(&node, &format!("img-{snap_count}"), DataMode::Real)
                        .unwrap();
                snap_count += 1;
                snapshot::snapshot_sqemu(&mut chain, &node, &format!("img-{snap_count}"))
                    .unwrap();
                d = ScalableDriver::new(
                    chain,
                    CacheConfig::new(16, 64 << 10),
                    clock.clone(),
                    CostModel::default(),
                    MemoryAccountant::new(),
                );
            }
            let vc = rng.below(VCLUSTERS);
            if rng.chance(0.6) {
                let byte = (step % 251) as u8 + 1;
                d.write(vc * CS + 3, &[byte; 5]).unwrap();
                model.insert(vc, byte);
            } else {
                let mut buf = [0u8; 5];
                d.read(vc * CS + 3, &mut buf).unwrap();
                let expect = model.get(&vc).copied().unwrap_or(0);
                assert_eq!(buf, [expect; 5], "vc={vc} step={step}");
            }
        }
        d.flush().unwrap();
        let report = qcheck::check_chain(d.chain()).unwrap();
        assert!(report.is_clean(), "{:?}", report.errors);
    });
}

#[test]
fn cow_never_mutates_backing_files() {
    forall(0xC0C0, 8, |rng| {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let mut chain = fresh_chain(&node);
        // populate the base, remember its exact file bytes
        for vc in 0..VCLUSTERS / 2 {
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            let mut data = vec![0u8; 64];
            rng.fill_bytes(&mut data);
            img.write_data(off, 0, &data).unwrap();
            img.set_l2_entry(vc, L2Entry::local(off, Some(0))).unwrap();
        }
        snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        let base = Arc::clone(chain.get(0).unwrap());
        let base_len = base.file_len();
        let mut base_bytes = vec![0u8; base_len as usize];
        base.backend().read_at(&mut base_bytes, 0).unwrap();

        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::new(16, 64 << 10),
            clock,
            CostModel::default(),
            MemoryAccountant::new(),
        );
        for _ in 0..30 {
            let voff = rng.below(VCLUSTERS * CS - 16);
            let mut data = vec![0u8; 16];
            rng.fill_bytes(&mut data);
            d.write(voff, &data).unwrap();
        }
        d.flush().unwrap();
        // the backing file is bit-identical
        assert_eq!(base.file_len(), base_len);
        let mut after = vec![0u8; base_len as usize];
        base.backend().read_at(&mut after, 0).unwrap();
        assert_eq!(after, base_bytes, "backing file mutated by COW");
    });
}

#[test]
fn active_stamps_always_agree_with_chain_walk() {
    forall(0x57A3, 8, |rng| {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let mut chain = fresh_chain(&node);
        for layer in 0..4 {
            for _ in 0..6 {
                let vc = rng.below(VCLUSTERS);
                let img = chain.active();
                let off = img.alloc_data_cluster().unwrap();
                img.set_l2_entry(vc, L2Entry::local(off, Some(img.chain_index())))
                    .unwrap();
            }
            snapshot::snapshot_sqemu(&mut chain, &node, &format!("img-{}", layer + 1))
                .unwrap();
        }
        let active = chain.active();
        for vc in 0..VCLUSTERS {
            let stamp = active.l2_entry(vc).unwrap().sqemu_view(active.chain_index());
            let walk = chain.resolve_walk(vc).unwrap();
            assert_eq!(stamp, walk, "vc={vc}");
        }
    });
}

#[test]
fn streaming_preserves_guest_visible_content() {
    forall(0x57EA, 6, |rng| {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let mut chain = fresh_chain(&node);
        let mut model: HashMap<u64, Vec<u8>> = HashMap::new();
        for layer in 0..5 {
            for _ in 0..5 {
                let vc = rng.below(VCLUSTERS);
                let img = chain.active();
                let off = img.alloc_data_cluster().unwrap();
                let mut data = vec![0u8; 32];
                rng.fill_bytes(&mut data);
                img.write_data(off, 0, &data).unwrap();
                img.set_l2_entry(vc, L2Entry::local(off, Some(img.chain_index())))
                    .unwrap();
                model.insert(vc, data);
            }
            snapshot::snapshot_sqemu(&mut chain, &node, &format!("img-{}", layer + 1))
                .unwrap();
        }
        let from = rng.below(3) as u16;
        let to = from + 1 + rng.below(2) as u16;
        snapshot::stream_merge(&mut chain, from, to).unwrap();
        let report = qcheck::check_chain(&chain).unwrap();
        assert!(report.is_clean(), "{:?}", report.errors);
        for (vc, data) in &model {
            match chain.resolve_walk(*vc).unwrap() {
                None => panic!("vc={vc} lost by streaming"),
                Some((bfi, off)) => {
                    let mut back = vec![0u8; 32];
                    chain.get(bfi).unwrap().read_data(off, 0, &mut back).unwrap();
                    assert_eq!(&back, data, "vc={vc}");
                }
            }
        }
    });
}

#[test]
fn slice_cache_never_exceeds_budget() {
    forall(0x10BE, 10, |rng| {
        let acct = MemoryAccountant::new();
        let cfg = CacheConfig::new(32, 4 << 10);
        let cap = cfg.capacity_slices();
        let mut c = SliceCache::new(cfg, &acct);
        for _ in 0..500 {
            let key = rng.below(64);
            if rng.chance(0.7) {
                c.insert(key, vec![0u64; 32]);
            } else {
                c.get(key);
            }
            assert!(c.resident_slices() <= cap, "over budget");
        }
    });
}

/// Backend that starts failing after a countdown — error-path injection.
struct Faulty {
    inner: sqemu::storage::mem::MemBackend,
    remaining: std::sync::atomic::AtomicI64,
}

impl sqemu::storage::backend::Backend for Faulty {
    fn read_at(&self, buf: &mut [u8], off: u64) -> anyhow::Result<()> {
        if self.remaining.fetch_sub(1, std::sync::atomic::Ordering::Relaxed) <= 0 {
            anyhow::bail!("injected I/O error (read)");
        }
        self.inner.read_at(buf, off)
    }

    fn write_at(&self, data: &[u8], off: u64) -> anyhow::Result<()> {
        if self.remaining.fetch_sub(1, std::sync::atomic::Ordering::Relaxed) <= 0 {
            anyhow::bail!("injected I/O error (write)");
        }
        self.inner.write_at(data, off)
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn truncate_to(&self, len: u64) -> anyhow::Result<()> {
        self.inner.truncate_to(len)
    }
}

#[test]
fn io_errors_propagate_without_panicking() {
    use sqemu::qcow::image::Image;
    use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
    use sqemu::vdisk::Driver;
    forall(0xFA11, 10, |rng| {
        let budget = 20 + rng.below(150) as i64;
        let backend: sqemu::storage::backend::BackendRef = Arc::new(Faulty {
            inner: sqemu::storage::mem::MemBackend::new(),
            remaining: std::sync::atomic::AtomicI64::new(budget),
        });
        let geom = Geometry::new(16, 8 << 20).unwrap();
        let Ok(img) = Image::create(
            "faulty",
            backend,
            geom,
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        ) else {
            return; // failed during create: also a valid error path
        };
        let Ok(chain) = Chain::new(Arc::new(img)) else { return };
        let clock = VirtClock::new();
        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::new(16, 64 << 10),
            clock,
            CostModel::default(),
            MemoryAccountant::new(),
        );
        // hammer until the injected failure fires; must surface as Err
        let mut saw_error = false;
        for i in 0..600u64 {
            let r = if i % 3 == 0 {
                d.write(i * 4096 % (8 << 20 - 1), &[1, 2, 3]).map(|_| ())
            } else {
                let mut b = [0u8; 64];
                d.read(i * 8192 % (8 << 20 - 1), &mut b).map(|_| ())
            };
            if r.is_err() {
                saw_error = true;
                break;
            }
        }
        assert!(saw_error, "budget {budget} never exhausted?");
    });
}

#[test]
fn interleaved_writes_snapshots_and_streams_stay_consistent() {
    use sqemu::vdisk::Driver;
    forall(0x1A7E, 6, |rng| {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let chain = fresh_chain(&node);
        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::new(16, 64 << 10),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let mut model: HashMap<u64, u8> = HashMap::new();
        let mut next = 0usize;
        for step in 0..80 {
            match rng.below(10) {
                0..=5 => {
                    // write
                    let vc = rng.below(VCLUSTERS);
                    let byte = (step % 250) as u8 + 1;
                    d.write(vc * CS + 7, &[byte; 3]).unwrap();
                    model.insert(vc, byte);
                }
                6..=7 => {
                    // read + verify
                    let vc = rng.below(VCLUSTERS);
                    let mut buf = [0u8; 3];
                    d.read(vc * CS + 7, &mut buf).unwrap();
                    let expect = model.get(&vc).copied().unwrap_or(0);
                    assert_eq!(buf, [expect; 3], "step {step} vc {vc}");
                }
                8 => {
                    // snapshot via the driver's paused-chain protocol
                    d.flush().unwrap();
                    next += 1;
                    let name = format!("img-{next}");
                    snapshot::snapshot_sqemu(d.chain_mut(), &node, &name).unwrap();
                    d.reopen().unwrap();
                }
                _ => {
                    // stream a window when deep enough
                    let len = d.chain().len() as u16;
                    if len >= 4 {
                        d.flush().unwrap();
                        let from = rng.below((len - 2) as u64) as u16;
                        let to = from + 1;
                        snapshot::stream_merge(d.chain_mut(), from, to).unwrap();
                        d.reopen().unwrap();
                    }
                }
            }
        }
        d.flush().unwrap();
        let report = qcheck::check_chain(d.chain()).unwrap();
        assert!(report.is_clean(), "{:?}", report.errors);
        // final full verification
        for (vc, byte) in &model {
            let mut buf = [0u8; 3];
            d.read(vc * CS + 7, &mut buf).unwrap();
            assert_eq!(buf, [*byte; 3], "final vc {vc}");
        }
    });
}
