//! Differential test: PJRT-executed artifacts == host kernels, the Rust
//! half of the L1 correctness contract (pytest covers Pallas vs ref.py).
//!
//! Requires `make artifacts`; skips (with a loud note) when absent so
//! `cargo test` works on a fresh checkout.

use sqemu::runtime::{host, Runtime, UNALLOCATED};
use sqemu::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = sqemu::runtime::default_artifacts_dir();
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests (no artifacts at {dir:?}): {e:#}");
            None
        }
    }
}

fn random_table(rng: &mut Rng, clusters: usize, files: i32, fill: f64) -> (Vec<i32>, Vec<i32>) {
    let mut off = vec![UNALLOCATED; clusters];
    let mut bfi = vec![UNALLOCATED; clusters];
    for i in 0..clusters {
        if rng.chance(fill) {
            off[i] = rng.below(1 << 20) as i32;
            bfi[i] = rng.below(files as u64) as i32;
        }
    }
    (off, bfi)
}

#[test]
fn loads_all_artifacts() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.platform(), "cpu");
    let names = rt.artifact_names();
    for expect in ["merge_l2", "stream_fold", "translate_direct", "translate_walk"] {
        assert!(names.iter().any(|n| n == expect), "missing {expect}");
    }
}

#[test]
fn translate_direct_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(42);
    for case in 0..3 {
        let clusters = [100, 4096, rt.manifest.clusters][case];
        let files = rt.manifest.chain as i32;
        let (off, bfi) = random_table(&mut rng, clusters, files, 0.8);
        // batch larger than one chunk to exercise chunking + padding
        let vbs: Vec<i32> = (0..rt.manifest.batch as i32 * 2 + 17)
            .map(|_| rng.below(clusters as u64) as i32)
            .collect();
        let (gb, go, gh) = rt.translate_direct(&off, &bfi, &vbs).unwrap();
        let (hb, ho, hh) = host::translate_direct(&off, &bfi, &vbs, rt.manifest.chain);
        assert_eq!(gb, hb, "bfi mismatch case {case}");
        assert_eq!(go, ho, "off mismatch case {case}");
        assert_eq!(gh, hh, "hist mismatch case {case}");
    }
}

#[test]
fn translate_walk_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(7);
    let clusters = 2048;
    for n_files in [1usize, 3, rt.manifest.chain] {
        let tables: Vec<Vec<i32>> = (0..n_files)
            .map(|_| {
                (0..clusters)
                    .map(|_| {
                        if rng.chance(0.4) {
                            rng.below(1 << 20) as i32
                        } else {
                            UNALLOCATED
                        }
                    })
                    .collect()
            })
            .collect();
        let vbs: Vec<i32> = (0..300).map(|_| rng.below(clusters as u64) as i32).collect();
        let (gb, go) = rt.translate_walk(&tables, &vbs).unwrap();
        let (hb, ho) = host::translate_walk(&tables, &vbs);
        assert_eq!(gb, hb, "bfi mismatch n_files={n_files}");
        assert_eq!(go, ho, "off mismatch n_files={n_files}");
    }
}

#[test]
fn merge_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(11);
    for _ in 0..3 {
        let c = 3000;
        let (off_v, bfi_v) = random_table(&mut rng, c, 32, 0.7);
        let (off_b, bfi_b) = random_table(&mut rng, c, 32, 0.7);
        let (go, gb) = rt.merge_l2(&off_v, &bfi_v, &off_b, &bfi_b).unwrap();
        let (ho, hb) = host::merge_l2(&off_v, &bfi_v, &off_b, &bfi_b);
        assert_eq!(go, ho);
        assert_eq!(gb, hb);
    }
}

#[test]
fn stream_fold_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(13);
    let c = 1024;
    for depth in [1usize, 4, rt.manifest.stream_depth] {
        let mut offs = Vec::new();
        let mut bfis = Vec::new();
        for _ in 0..depth {
            let (o, b) = random_table(&mut rng, c, 64, 0.5);
            offs.push(o);
            bfis.push(b);
        }
        let (go, gb) = rt.stream_fold(&offs, &bfis).unwrap();
        let (ho, hb) = host::stream_fold(&offs, &bfis);
        assert_eq!(go, ho, "off mismatch depth={depth}");
        assert_eq!(gb, hb, "bfi mismatch depth={depth}");
    }
}

#[test]
fn merge_is_idempotent_via_runtime() {
    // property: merging a table into itself is the identity
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(17);
    let (off, bfi) = random_table(&mut rng, 2000, 16, 0.6);
    let (o2, b2) = rt.merge_l2(&off, &bfi, &off, &bfi).unwrap();
    assert_eq!(o2, off);
    assert_eq!(b2, bfi);
}
