use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::control::StateStore;
use sqemu::coordinator::server::{CoordinatorConfig, VmChain};
use sqemu::coordinator::{Coordinator, NodeSet, VmConfig};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::image::DataMode;
use sqemu::storage::node::StorageNode;
use sqemu::vdisk::DriverKind;
use std::sync::Arc;

#[test]
fn duplicate_launch_drops_live_lease() {
    let clock = VirtClock::new();
    let data = vec![StorageNode::new("node-0", clock.clone(), CostModel::default())];
    let nodes = Arc::new(NodeSet::new(data).unwrap());
    let meta = StorageNode::new("meta-0", clock.clone(), CostModel::default());
    let store = StateStore::open(meta).unwrap();
    let c = Coordinator::new(
        Arc::clone(&nodes),
        clock.clone(),
        CoordinatorConfig { lease_ttl_ns: 5_000_000_000, ..Default::default() },
        None,
    );
    c.attach_control(Arc::clone(&store), "c1").unwrap();
    let pin = nodes.pinned("node-0").unwrap();
    generate(
        &pin,
        &ChainSpec {
            disk_size: 1 << 20,
            chain_len: 2,
            populated: 0.3,
            stamped: true,
            data_mode: DataMode::Real,
            prefix: "vm-0".to_string(),
            seed: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let cfg = || VmConfig {
        driver: DriverKind::Scalable,
        cache: CacheConfig::new(16, 32 << 10),
        chain: VmChain::Existing {
            active_name: "vm-0-1".to_string(),
            data_mode: DataMode::Real,
        },
    };
    c.launch_vm("vm-0", cfg()).unwrap();
    assert!(store.lease_of("vm-0").is_some(), "launch took the lease");
    // duplicate launch attempt (operator retry): must fail...
    let err = c.launch_vm("vm-0", cfg()).unwrap_err();
    assert!(err.to_string().contains("already running"), "{err:#}");
    // ...but must NOT release the running VM's lease
    assert!(
        store.lease_of("vm-0").is_some(),
        "duplicate launch released the live lease — VM now runs unleased"
    );
}
