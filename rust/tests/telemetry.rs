//! The fleet telemetry plane, end to end: the Prometheus-text scrape
//! must parse, counters must stay monotone while the fleet serves, a
//! 64-VM control-attached fleet must export families from every
//! subsystem, and the exported metric-name inventory must match the
//! checked-in `telemetry/metrics.txt` (the CI `observability` diff).

use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::control::StateStore;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{
    Coordinator, CoordinatorConfig, JobSpec, NodeSet, VmConfig,
};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::image::DataMode;
use sqemu::storage::node::StorageNode;
use sqemu::vdisk::DriverKind;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const CS: u64 = 64 << 10;

/// A control-attached fleet: `vms` 2-deep synthetic chains spread over
/// `n_nodes` data nodes, capacity subsystem on, every 8th VM
/// trace-sampled — the full-featured shape `sqemu metrics` runs.
fn control_fleet(n_nodes: usize, vms: usize) -> Arc<Coordinator> {
    let clock = VirtClock::new();
    let data = (0..n_nodes)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let nodes = Arc::new(NodeSet::new(data).unwrap());
    let meta = StorageNode::new("meta-0", clock.clone(), CostModel::default());
    let store = StateStore::open(meta).unwrap();
    let coord = Coordinator::new(
        Arc::clone(&nodes),
        clock,
        CoordinatorConfig {
            capacity: true,
            trace_sample: 8,
            lease_ttl_ns: 10_000_000_000,
            ..Default::default()
        },
        None,
    );
    coord.attach_control(store, "coord-test").unwrap();
    coord.campaign().unwrap();
    let threads = 8.min(vms.max(1));
    let mut handles = Vec::new();
    for t in 0..threads {
        let coord = Arc::clone(&coord);
        let nodes = Arc::clone(&nodes);
        handles.push(std::thread::spawn(move || {
            for v in (t..vms).step_by(threads) {
                let name = format!("tvm-{v:02}");
                let pin = nodes.pinned(&format!("node-{}", v % n_nodes)).unwrap();
                generate(
                    &pin,
                    &ChainSpec {
                        disk_size: 1 << 20,
                        chain_len: 2,
                        populated: 0.2,
                        stamped: true,
                        data_mode: DataMode::Synthetic,
                        prefix: name.clone(),
                        seed: 0x7E1E ^ v as u64,
                        ..Default::default()
                    },
                )
                .unwrap();
                coord
                    .launch_vm(
                        &name,
                        VmConfig {
                            driver: DriverKind::Scalable,
                            cache: CacheConfig::new(16, 32 << 10),
                            chain: VmChain::Existing {
                                active_name: format!("{name}-1"),
                                data_mode: DataMode::Synthetic,
                            },
                        },
                    )
                    .unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    coord
}

/// A plain (no control plane) fleet with generated chains and a little
/// guest traffic, for the parser/monotonicity tests.
fn busy_fleet(vms: usize) -> Arc<Coordinator> {
    let coord = Coordinator::with_fresh_nodes(2).unwrap();
    for v in 0..vms {
        let name = format!("vm-{v}");
        let client = coord
            .launch_vm(
                &name,
                VmConfig {
                    driver: DriverKind::Scalable,
                    cache: CacheConfig::new(16, 32 << 10),
                    chain: VmChain::Generate(ChainSpec {
                        disk_size: 4 << 20,
                        chain_len: 2,
                        populated: 0.2,
                        stamped: true,
                        data_mode: DataMode::Synthetic,
                        prefix: name.clone(),
                        seed: 0xBEE ^ v as u64,
                        ..Default::default()
                    }),
                },
            )
            .unwrap();
        for k in 0..8u64 {
            client.write(k * CS, vec![v as u8; 512]).unwrap();
            client.read(k * CS, 4096).unwrap();
        }
        client.flush().unwrap();
    }
    coord
}

/// Golden parse: every line of a real scrape is either a well-formed
/// comment or a `series value timestamp` sample whose family was
/// declared, typed, and (for counters) named `*_total`; histogram
/// buckets are cumulative and agree with `_count`.
#[test]
fn scrape_parses_as_prometheus_text() {
    let coord = busy_fleet(4);
    let text = coord.telemetry().render();
    let mut typed: BTreeMap<String, String> = BTreeMap::new();
    let mut helped = 0usize;
    let mut samples = 0usize;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest.split_once(' ').expect("HELP has name + text");
            assert!(!name.is_empty() && !help.is_empty(), "bare HELP: {line}");
            helped += 1;
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest.split_once(' ').expect("TYPE has name + kind");
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind),
                "unknown TYPE kind: {line}"
            );
            assert!(
                typed.insert(name.to_string(), kind.to_string()).is_none(),
                "family typed twice: {name}"
            );
        } else {
            assert!(!line.is_empty(), "blank line in scrape");
            let fields: Vec<&str> = line.split_whitespace().collect();
            assert_eq!(fields.len(), 3, "sample is `series value ts`: {line}");
            let series = fields[0];
            let name = series.split('{').next().unwrap();
            // histogram sample names carry a suffix over the family name
            let family = ["_bucket", "_sum", "_count"]
                .iter()
                .find_map(|s| {
                    name.strip_suffix(s).filter(|f| typed.contains_key(*f))
                })
                .unwrap_or(name);
            let kind = typed
                .get(family)
                .unwrap_or_else(|| panic!("sample before its TYPE: {line}"));
            if kind == "counter" {
                assert!(
                    family.ends_with("_total"),
                    "counter family must end in _total: {family}"
                );
                fields[1].parse::<u64>().unwrap_or_else(|_| {
                    panic!("counter value not a u64: {line}")
                });
            } else {
                fields[1].parse::<f64>().unwrap_or_else(|_| {
                    panic!("unparsable sample value: {line}")
                });
            }
            fields[2].parse::<u64>().unwrap_or_else(|_| {
                panic!("timestamp not integer milliseconds: {line}")
            });
            if let Some(open) = series.find('{') {
                assert!(series.ends_with('}'), "unterminated labels: {line}");
                for pair in series[open + 1..series.len() - 1].split(',') {
                    let (k, v) = pair.split_once('=').unwrap_or_else(|| {
                        panic!("label pair without '=': {line}")
                    });
                    assert!(!k.is_empty(), "empty label key: {line}");
                    assert!(
                        v.starts_with('"') && v.ends_with('"') && v.len() >= 2,
                        "unquoted label value: {line}"
                    );
                }
            }
            samples += 1;
        }
    }
    assert_eq!(helped, typed.len(), "every family has HELP and TYPE");
    assert!(samples > typed.len(), "families render at least one sample");

    // histogram structure on the fleet latency aggregate: cumulative
    // buckets, +Inf last, equal to _count
    let buckets: Vec<u64> = text
        .lines()
        .filter(|l| l.starts_with("sqemu_guest_req_latency_ns_bucket"))
        .map(|l| l.split_whitespace().nth(1).unwrap().parse().unwrap())
        .collect();
    assert!(buckets.len() >= 2, "latency histogram has buckets");
    assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "buckets cumulative");
    let count: u64 = text
        .lines()
        .find(|l| l.starts_with("sqemu_guest_req_latency_ns_count"))
        .map(|l| l.split_whitespace().nth(1).unwrap().parse().unwrap())
        .unwrap();
    assert_eq!(*buckets.last().unwrap(), count, "+Inf bucket equals _count");
    assert!(count > 0, "the fleet served requests");
    coord.shutdown();
}

/// Every `*_total` series key present in consecutive scrapes must never
/// decrease while guest load runs (steady fleet, no decommission) — the
/// watermark-reap and ledger designs exist for exactly this property.
#[test]
fn counters_stay_monotone_under_load() {
    fn total_series(text: &str) -> BTreeMap<String, u64> {
        text.lines()
            .filter(|l| !l.starts_with('#'))
            .filter_map(|l| {
                let mut it = l.split_whitespace();
                let series = it.next()?;
                let value = it.next()?;
                if !series.split('{').next().unwrap().ends_with("_total") {
                    return None;
                }
                Some((series.to_string(), value.parse().ok()?))
            })
            .collect()
    }
    let coord = busy_fleet(4);
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for name in coord.vm_names() {
        let client = coord.client(&name).unwrap();
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let mut k = 0u64;
            while !stop.load(Ordering::Relaxed) {
                if client.write((k % 32) * CS, vec![0x42; 512]).is_err() {
                    break;
                }
                let reqs: Vec<(u64, usize)> =
                    (0..4).map(|j| ((k + j) % 32 * CS, 4096)).collect();
                if client.readv(&reqs).is_err() {
                    break;
                }
                k += 1;
            }
        }));
    }
    let mut prev = total_series(&coord.telemetry().render());
    assert!(!prev.is_empty(), "no _total series in the scrape");
    for scrape in 0..15 {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let next = total_series(&coord.telemetry().render());
        for (key, old) in &prev {
            if let Some(new) = next.get(key) {
                assert!(
                    new >= old,
                    "counter went backwards on scrape {scrape}: {key} {old} -> {new}"
                );
            }
        }
        prev = next;
    }
    stop.store(true, Ordering::Relaxed);
    for w in workers {
        w.join().unwrap();
    }
    coord.shutdown();
}

/// The acceptance shape: a 64-VM control-attached fleet under load
/// emits families from all eight subsystems in one scrape.
#[test]
fn sixty_four_vm_fleet_exports_all_eight_subsystems() {
    let coord = control_fleet(2, 64);
    for name in coord.vm_names() {
        let client = coord.client(&name).unwrap();
        for k in 0..4u64 {
            client.write(k * CS, vec![0u8; CS as usize]).unwrap();
            client.read(k * CS, 4096).unwrap();
        }
        client.flush().unwrap();
    }
    // move the job/gc counters: one live stream job plus a sweep
    let job = coord.start_job("tvm-00", JobSpec::stream(0)).unwrap();
    coord.wait_job(&job);
    coord.run_gc(0).unwrap();

    let text = coord.telemetry().render();
    for family in [
        "sqemu_guest_reads_total",            // guest counters
        "sqemu_guest_req_latency_ns",         // guest latency aggregate
        "sqemu_shard_served_total",           // coordinator shards
        "sqemu_node_used_bytes",              // storage capacity
        "sqemu_iosched_busy_ns_total",        // storage device time
        "sqemu_jobs_started_total",           // blockjob ledger
        "sqemu_gc_runs_total",                // gc
        "sqemu_dedup_extents",                // dedup
        "sqemu_migrate_convergence_lag_clusters", // migrate
        "sqemu_control_epoch",                // control plane
        "sqemu_trace_events_total",           // tracing
    ] {
        assert!(
            text.contains(&format!("# TYPE {family} ")),
            "missing family {family}"
        );
    }
    assert_eq!(
        text.matches("sqemu_guest_reads_total{vm=").count(),
        64,
        "one reads series per VM"
    );
    // the stream job landed in the ledger as a completed stream
    let started: u64 = text
        .lines()
        .find(|l| l.starts_with("sqemu_jobs_started_total{kind=\"stream\"}"))
        .map(|l| l.split_whitespace().nth(1).unwrap().parse().unwrap())
        .unwrap();
    assert!(started >= 1, "stream job missing from the jobs family");
    coord.shutdown_clean().unwrap();
}

/// The exported family inventory IS the checked-in one. Regenerate with
/// `cargo run --release -- metrics --names > telemetry/metrics.txt`
/// whenever a collector adds or renames a family.
#[test]
fn metric_inventory_matches_checked_in_list() {
    let expected: Vec<&str> = include_str!("../../telemetry/metrics.txt")
        .lines()
        .filter(|l| !l.is_empty())
        .collect();
    let coord = control_fleet(2, 2);
    let names = coord.telemetry().metric_names();
    assert_eq!(
        names, expected,
        "telemetry/metrics.txt is stale — regenerate it with \
         `sqemu metrics --names`"
    );
    coord.shutdown_clean().unwrap();
}
