//! Vectored I/O invariants (DESIGN.md §9):
//!
//! * `readv`/`writev` are bit-for-bit identical to the equivalent
//!   sequence of scalar `read`/`write` calls, on random stamped and
//!   vanilla chains, under both drivers;
//! * a warm sequential 1 MiB `readv` on a 500-deep stamped chain costs
//!   one slice-group cache probe and ONE coalesced device read;
//! * vectored sequential 4 KiB reads are >= 2x the per-request path in
//!   simulated throughput under the default cost model;
//! * the coordinator batch path executes in submission order (a write is
//!   visible to later reads of the same batch) and feeds the new
//!   `batched_ops`/`merged_ios` stats.

use sqemu::bench::smoke::{device_ios, seq4k_compare};
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::dedup::CapacityPolicy;
use sqemu::coordinator::server::{BatchOp, BatchReply, VmChain};
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::qcheck;
use sqemu::storage::node::StorageNode;
use sqemu::util::prop::forall;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::{Driver, DriverKind};

const CS: u64 = 64 << 10;

fn spec(stamped: bool, seed: u64, prefix: &str) -> ChainSpec {
    ChainSpec {
        disk_size: 64 * CS,
        chain_len: 6,
        populated: 0.5,
        stamped,
        data_mode: DataMode::Real,
        prefix: prefix.into(),
        seed,
        ..Default::default()
    }
}

/// Two bit-identical chains on separate nodes (generation is
/// deterministic), one per driver design.
fn drivers(stamped: bool, seed: u64) -> (ScalableDriver, VanillaDriver) {
    let ca = VirtClock::new();
    let na = StorageNode::new("a", ca.clone(), CostModel::default());
    let chain_a = generate(&*na, &spec(stamped, seed, "v")).unwrap();
    let cb = VirtClock::new();
    let nb = StorageNode::new("b", cb.clone(), CostModel::default());
    let chain_b = generate(&*nb, &spec(stamped, seed, "v")).unwrap();
    let cfg = CacheConfig::new(16, 128 << 10);
    (
        ScalableDriver::new(chain_a, cfg, ca, CostModel::default(), MemoryAccountant::new()),
        VanillaDriver::new(chain_b, cfg, cb, CostModel::default(), MemoryAccountant::new()),
    )
}

fn readv_into(d: &mut dyn Driver, reqs: &[(u64, usize)]) -> Vec<Vec<u8>> {
    let mut bufs: Vec<Vec<u8>> = reqs.iter().map(|r| vec![0u8; r.1]).collect();
    {
        let mut iovs: Vec<(u64, &mut [u8])> = reqs
            .iter()
            .zip(bufs.iter_mut())
            .map(|(r, b)| (r.0, b.as_mut_slice()))
            .collect();
        d.readv(&mut iovs).unwrap();
    }
    bufs
}

#[test]
fn readv_matches_scalar_reads_bit_for_bit() {
    forall(0x5EC1, 6, |rng| {
        let stamped = rng.chance(0.5);
        let (mut ds, mut dv) = drivers(stamped, rng.below(1 << 20));
        for _ in 0..8 {
            let n = 1 + rng.below(6) as usize;
            let reqs: Vec<(u64, usize)> = (0..n)
                .map(|_| {
                    let len = 1 + rng.below(3 * CS) as usize;
                    let voff = rng.below(64 * CS - len as u64);
                    (voff, len)
                })
                .collect();
            let got_s = readv_into(&mut ds, &reqs);
            let got_v = readv_into(&mut dv, &reqs);
            for (i, &(voff, len)) in reqs.iter().enumerate() {
                let mut reference = vec![0u8; len];
                ds.read(voff, &mut reference).unwrap();
                assert_eq!(got_s[i], reference, "scalable voff={voff} len={len}");
                assert_eq!(got_v[i], reference, "vanilla voff={voff} len={len}");
            }
        }
    });
}

#[test]
fn writev_matches_scalar_writes_bit_for_bit() {
    forall(0x5EC2, 5, |rng| {
        let stamped = rng.chance(0.5);
        let (mut ds, mut dv) = drivers(stamped, rng.below(1 << 20));
        for _ in 0..6 {
            let n = 1 + rng.below(5) as usize;
            let reqs: Vec<(u64, Vec<u8>)> = (0..n)
                .map(|_| {
                    let len = 1 + rng.below(200) as usize;
                    let voff = rng.below(64 * CS - len as u64);
                    let mut data = vec![0u8; len];
                    rng.fill_bytes(&mut data);
                    (voff, data)
                })
                .collect();
            // batched on the scalable driver, scalar loop on vanilla
            let iovs: Vec<(u64, &[u8])> =
                reqs.iter().map(|(v, d)| (*v, d.as_slice())).collect();
            ds.writev(&iovs).unwrap();
            for (v, d) in &reqs {
                dv.write(*v, d).unwrap();
            }
        }
        let mut ba = vec![0u8; CS as usize];
        let mut bb = vec![0u8; CS as usize];
        for vc in 0..64u64 {
            ds.read(vc * CS, &mut ba).unwrap();
            dv.read(vc * CS, &mut bb).unwrap();
            assert_eq!(ba, bb, "vc={vc} diverged");
        }
        ds.flush().unwrap();
        dv.flush().unwrap();
        assert!(qcheck::check_chain(ds.chain()).unwrap().is_clean());
        assert!(qcheck::check_chain(dv.chain()).unwrap().is_clean());
    });
}

/// The acceptance criterion: on a warm 500-deep stamped chain, a 1 MiB
/// sequential readv performs one batched cache probe (16 clusters share
/// one 512-entry slice) and ONE coalesced device read for the whole
/// physically contiguous run.
#[test]
fn warm_seq_readv_on_deep_chain_one_probe_one_device_read() {
    let clock = VirtClock::new();
    let node = StorageNode::new("deep", clock.clone(), CostModel::default());
    let chain = generate(
        &*node,
        &ChainSpec {
            disk_size: 64 * CS,
            chain_len: 500,
            populated: 0.0, // our writes below populate the active volume
            stamped: true,
            data_mode: DataMode::Real,
            prefix: "d".into(),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(chain.len(), 500);
    let mut d = ScalableDriver::new(
        chain,
        CacheConfig::new(512, 1 << 20),
        clock,
        CostModel::default(),
        MemoryAccountant::new(),
    );
    // force the L2-table allocation first, then lay down 16 physically
    // contiguous clusters in the active volume
    d.write(17 * CS, &[1u8; 4]).unwrap();
    let data: Vec<u8> = (0..(16 * CS) as usize).map(|i| (i % 251) as u8).collect();
    d.write(0, &data).unwrap();

    let mut buf = vec![0u8; (16 * CS) as usize];
    let readv_once = |d: &mut ScalableDriver, buf: &mut Vec<u8>| {
        let mut iovs: Vec<(u64, &mut [u8])> = vec![(0, buf.as_mut_slice())];
        d.readv(&mut iovs).unwrap();
    };
    readv_once(&mut d, &mut buf); // warm
    let c0 = d.counters();
    let v0 = d.vec_io();
    let ios0 = device_ios(&d);
    readv_once(&mut d, &mut buf);
    let c1 = d.counters();
    let v1 = d.vec_io();
    let ios1 = device_ios(&d);

    let probes = c1.per_file_lookups.iter().sum::<u64>()
        - c0.per_file_lookups.iter().sum::<u64>();
    assert_eq!(probes, 1, "16 clusters in one slice -> one batched probe");
    assert_eq!(c1.misses, c0.misses, "warm cache: no slice fetch");
    assert_eq!(v1.merged_ios - v0.merged_ios, 1, "one coalesced run");
    assert_eq!(v1.coalesced_bytes - v0.coalesced_bytes, 16 * CS);
    assert_eq!(ios1 - ios0, 1, "exactly one device read for the 1 MiB");
    assert_eq!(buf, data, "content intact through the coalesced path");
}

/// The acceptance criterion: vectored sequential 4 KiB reads >= 2x the
/// per-request path in simulated throughput (default cost model; the
/// per-request path pays one seek per 4 KiB, the vectored one one seek
/// per contiguous run).
#[test]
fn vectored_sequential_throughput_at_least_2x_scalar() {
    let clock = VirtClock::new();
    let node = StorageNode::new("tp", clock.clone(), CostModel::default());
    let chain = generate(
        &*node,
        &ChainSpec {
            disk_size: 16 << 20,
            chain_len: 100,
            populated: 1.0,
            stamped: true,
            data_mode: DataMode::Synthetic,
            prefix: "tp".into(),
            ..Default::default()
        },
    )
    .unwrap();
    let geom = *chain.active().geom();
    let mut d = ScalableDriver::new(
        chain,
        CacheConfig::full_disk(&geom),
        clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    let region: u64 = 4 << 20;
    let cmp = seq4k_compare(&mut d, &clock, region).unwrap();
    assert!(
        cmp.vectored_ns * 2 <= cmp.scalar_ns,
        "vectored {} ns not 2x faster than scalar {} ns",
        cmp.vectored_ns,
        cmp.scalar_ns
    );
    assert!(
        cmp.vectored_device_ios < cmp.scalar_device_ios / 2,
        "vectored path must merge device reads ({} vs {})",
        cmp.vectored_device_ios,
        cmp.scalar_device_ios
    );
}

/// Capacity satellite (DESIGN.md §13): `OFLAG_ZERO` clusters and
/// unallocated holes are served from the shared zero page. Once table
/// metadata is warm, reading them — scalar or vectored — performs ZERO
/// device I/O. Before the capacity subsystem, the all-zero write stored
/// a real data cluster and the device_ios assertion failed.
#[test]
fn zero_clusters_and_holes_cost_no_device_io() {
    let mk = |name: &str| {
        let clock = VirtClock::new();
        let node = StorageNode::new(name, clock.clone(), CostModel::default());
        let chain = generate(
            &*node,
            &ChainSpec {
                disk_size: 64 * CS,
                chain_len: 1,
                populated: 0.0,
                stamped: true,
                data_mode: DataMode::Real,
                prefix: "z".into(),
                ..Default::default()
            },
        )
        .unwrap();
        (chain, clock)
    };
    let (ca, clka) = mk("za");
    let (cb, clkb) = mk("zb");
    let cfg = CacheConfig::new(16, 128 << 10);
    let mut ds =
        ScalableDriver::new(ca, cfg, clka, CostModel::default(), MemoryAccountant::new());
    let mut dv =
        VanillaDriver::new(cb, cfg, clkb, CostModel::default(), MemoryAccountant::new());
    for d in [&mut ds as &mut dyn Driver, &mut dv as &mut dyn Driver] {
        d.set_capacity_policy(CapacityPolicy {
            zero_detect: true,
            ..Default::default()
        });
        d.write(3 * CS, &vec![0u8; CS as usize]).unwrap();
        d.flush().unwrap();
        // the write must have become a zero entry, not a data cluster
        assert!(d.chain().active().l2_entry(3).unwrap().is_zero_cluster());
        // warm the table metadata, then count device I/O
        let mut buf = vec![0u8; CS as usize];
        d.read(3 * CS, &mut buf).unwrap();
        d.read(5 * CS, &mut buf).unwrap(); // never written: a hole
        let ios0 = device_ios(&*d);
        let got = readv_into(&mut *d, &[(3 * CS, CS as usize), (5 * CS + 7, 300)]);
        assert!(got.iter().all(|b| b.iter().all(|&x| x == 0)));
        let mut s = vec![0u8; CS as usize];
        d.read(3 * CS, &mut s).unwrap();
        assert!(s.iter().all(|&x| x == 0));
        assert_eq!(
            device_ios(&*d),
            ios0,
            "zero/hole reads must not touch the device"
        );
    }
}

/// Coordinator batches: in-order execution (read-your-batched-write),
/// scatter-gather replies, and the new per-VM stats.
#[test]
fn coordinator_batch_orders_and_counts() {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    let c = coord
        .launch_vm(
            "vm",
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(64, 256 << 10),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: 8 << 20,
                    chain_len: 3,
                    populated: 0.3,
                    stamped: true,
                    data_mode: DataMode::Real,
                    prefix: "b".into(),
                    ..Default::default()
                }),
            },
        )
        .unwrap();
    // a write is visible to later reads of the same batch
    let replies = c
        .submit(vec![
            BatchOp::Write { voff: 1 << 20, data: vec![0xAB; 64] },
            BatchOp::Read { voff: 1 << 20, len: 64 },
            BatchOp::Write { voff: (1 << 20) + 64, data: vec![0xCD; 32] },
            BatchOp::Read { voff: (1 << 20) + 64, len: 32 },
        ])
        .unwrap();
    assert!(matches!(replies[0], BatchReply::Write));
    match (&replies[1], &replies[3]) {
        (BatchReply::Read(a), BatchReply::Read(b)) => {
            assert_eq!(a.as_slice(), &[0xABu8; 64][..]);
            assert_eq!(b.as_slice(), &[0xCDu8; 32][..]);
        }
        other => panic!("unexpected replies: {other:?}"),
    }
    // sequential batched reads over freshly written clusters coalesce
    c.write(0, vec![0x11; 128 << 10]).unwrap();
    let seq: Vec<(u64, usize)> = (0..32).map(|i| (i * 4096, 4096)).collect();
    let bufs = c.readv(&seq).unwrap();
    for (i, &(voff, len)) in seq.iter().enumerate() {
        assert_eq!(bufs[i], c.read(voff, len).unwrap(), "i={i}");
    }
    let stats = coord.vm_stats("vm").unwrap();
    assert_eq!(stats.reads, 2 + 32 + 32);
    assert_eq!(stats.writes, 3);
    assert!(stats.batched_ops >= 36, "batched_ops={}", stats.batched_ops);
    assert!(stats.merged_ios >= 1, "sequential batched reads must coalesce");
    assert!(stats.coalesced_bytes > 0);
    coord.shutdown();
}
