// Fixture: one real finding suppressed by the allowlist, plus one
// allowlist entry that matches nothing and must be reported stale.

pub struct Shard {
    stash: Mutex<Vec<u64>>,
}

impl Shard {
    pub fn serve(&self, v: u64) {
        let mut g = self.stash.lock().unwrap();
        g.push(v);
    }
}
