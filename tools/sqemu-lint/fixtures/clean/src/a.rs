// Fixture: everything in order — ranked locks acquired outer-to-inner,
// an annotated write-ahead persist, and a fenced flip.

pub struct S {
    outer: Mutex<u8>,
    inner: Mutex<u8>,
}

impl S {
    pub fn nested(&self) {
        let g = self.outer.lock().unwrap();
        let h = self.inner.lock().unwrap();
        drop(h);
        drop(g);
    }
}

impl Store {
    pub fn apply(&mut self, rec: &Rec) {
        // lint: durable-before(rec)
        self.log.persist(rec);
        // lint: mutates(rec)
        self.view.apply(rec);
    }

    pub fn compact(&mut self, buf: &[u8]) {
        self.log.write_at(8, buf);
        self.log.flush();
        // lint: index-flip(generation)
        self.ptr.write_at(0, &self.word);
    }
}
