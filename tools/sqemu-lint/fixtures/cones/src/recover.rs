// Fixture for the panic/index cones: recover_index panics and indexes;
// decode shows the fallible style the cone demands.

pub fn recover_index(buf: &[u8]) -> u16 {
    let lo = buf[0];
    let hi = buf.get(1).copied().unwrap();
    u16::from_le_bytes([lo, hi])
}

pub fn decode(rest: &[u8]) -> Option<u32> {
    let len_bytes: [u8; 4] = rest.get(0..4)?.try_into().ok()?;
    Some(u32::from_le_bytes(len_bytes))
}
