// Fixture: two functions acquire the same pair of locks in opposite
// orders — the analyzer must report a lock-cycle.

pub struct S {
    a: Mutex<u8>,
    b: Mutex<u8>,
}

impl S {
    pub fn ab(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }

    pub fn ba(&self) {
        let g = self.b.lock().unwrap();
        let h = self.a.lock().unwrap();
        drop(h);
        drop(g);
    }
}
