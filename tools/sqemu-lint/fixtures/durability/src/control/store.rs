// Fixture for the durability-ordering lints. Each fn isolates one case.

impl Store {
    // Missing annotation entirely -> durability-unannotated.
    pub fn unannotated(&mut self, rec: &Rec) {
        self.log.persist(rec);
    }

    // Write-ahead claim with no paired mutation -> durability-unpaired.
    pub fn unpaired(&mut self, rec: &Rec) {
        // lint: durable-before(rec)
        self.log.persist(rec);
    }

    // Properly paired write-ahead: clean.
    pub fn good(&mut self, rec: &Rec) {
        // lint: durable-before(rec)
        self.log.persist(rec);
        // lint: mutates(rec)
        self.view.apply(rec);
    }

    // Pointer flip without `lint: index-flip` -> durability-flip-unflagged.
    pub fn flip_unflagged(&mut self) {
        self.ptr.write_at(0, &self.word);
    }

    // Journal write not flushed before the flip -> durability-missing-flush.
    pub fn flip_unflushed(&mut self, buf: &[u8]) {
        self.log.write_at(8, buf);
        // lint: index-flip(generation)
        self.ptr.write_at(0, &self.word);
    }

    // Fenced flip: clean.
    pub fn flip_fenced(&mut self, buf: &[u8]) {
        self.log.write_at(8, buf);
        self.log.flush();
        // lint: index-flip(generation)
        self.ptr.write_at(0, &self.word);
    }
}
