// Fixture: the nesting a -> b contradicts the checked-in ranks
// (a=20, b=10); lock c has no rank; rank m.zz names a dead lock.

pub struct S {
    a: Mutex<u8>,
    b: Mutex<u8>,
    c: Mutex<u8>,
}

impl S {
    pub fn nested(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }
}
