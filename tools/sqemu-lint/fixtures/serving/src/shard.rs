// Fixture: the serving pass reaches a blocking lock through a callee;
// the transitive summary must surface it.

pub struct Shard {
    stash: Mutex<Vec<u64>>,
}

impl Shard {
    fn complete(&self, v: u64) {
        let mut g = self.stash.lock().unwrap();
        g.push(v);
    }

    pub fn serve(&self, v: u64) {
        self.complete(v);
    }
}
