//! Enforcement cones: panic-free recovery code, index-free parsers, and
//! lock-free shard-executor serving passes.

use crate::config::Config;
use crate::lockgraph::{FnKey, LockAnalysis};
use crate::report::Finding;
use crate::scan::{find_words, is_ident, skip_ws, skip_ws_back, SourceFile};

/// `.name(` with whitespace tolerance around the dot and paren.
pub fn dot_call(line: &[u8], name: &str) -> bool {
    for p in find_words(line, name) {
        let after = skip_ws(line, p + name.len());
        if after >= line.len() || line[after] != b'(' {
            continue;
        }
        let b = skip_ws_back(line, p);
        if b > 0 && line[b - 1] == b'.' {
            return true;
        }
    }
    false
}

/// `name!(` — a panicking macro invocation.
fn macro_call(line: &[u8], name: &str) -> bool {
    for p in find_words(line, name) {
        let bang = p + name.len();
        if bang >= line.len() || line[bang] != b'!' {
            continue;
        }
        let after = skip_ws(line, bang + 1);
        if after < line.len() && line[after] == b'(' {
            return true;
        }
    }
    false
}

/// Any panic path: `.unwrap(` / `.expect(` / `panic!(` / `unreachable!(`
/// / `todo!(` / `unimplemented!(`.
pub fn panic_on_line(line: &str) -> bool {
    let s = line.as_bytes();
    dot_call(s, "unwrap")
        || dot_call(s, "expect")
        || macro_call(s, "panic")
        || macro_call(s, "unreachable")
        || macro_call(s, "todo")
        || macro_call(s, "unimplemented")
}

/// Slice-index expression `chain[` (the last dotted segment must be a
/// lowercase/underscore identifier, so `vec![`, `#[`, and `[u8; 4]`
/// types don't count).
pub fn index_on_line(line: &str) -> bool {
    let s = line.as_bytes();
    for (p, &b) in s.iter().enumerate() {
        if b != b'[' {
            continue;
        }
        let q = skip_ws_back(s, p);
        if q == 0 || !is_ident(s[q - 1]) {
            continue;
        }
        let mut d = q;
        while d > 0 && is_ident(s[d - 1]) {
            d -= 1;
        }
        let seg0 = s[d];
        if seg0.is_ascii_lowercase() || seg0 == b'_' {
            return true;
        }
    }
    false
}

/// Panic-cone and index-cone findings for one file.
pub fn cone_findings(sf: &SourceFile, cfg: &Config) -> Vec<Finding> {
    let mut out = Vec::new();
    let in_panic_file = cfg.panic_files.iter().any(|f| f == &sf.rel);
    let prefix = cfg
        .panic_fn_prefixes
        .iter()
        .find(|(f, _)| f == &sf.rel)
        .map(|(_, p)| p.as_str());
    if !in_panic_file && prefix.is_none() {
        return out;
    }
    let in_index_file = cfg.index_files.iter().any(|f| f == &sf.rel);
    for f in &sf.fns {
        if sf.in_test(f.start_line) {
            continue;
        }
        if !in_panic_file {
            let Some(p) = prefix else { continue };
            if !f.name.starts_with(p) {
                continue;
            }
        }
        let key = format!("{}:{}", sf.rel, f.name);
        for idx in f.start_line - 1..f.end_line.min(sf.code_lines.len()) {
            let line = &sf.code_lines[idx];
            if panic_on_line(line) {
                out.push(Finding::new(
                    "panic-cone",
                    key.clone(),
                    &sf.rel,
                    idx + 1,
                    format!(
                        "panic path in recovery cone fn {}: `{}`",
                        f.name,
                        line.trim().chars().take(80).collect::<String>()
                    ),
                ));
            }
            if in_index_file && index_on_line(line) {
                out.push(Finding::new(
                    "index-cone",
                    key.clone(),
                    &sf.rel,
                    idx + 1,
                    format!(
                        "slice indexing in parse cone fn {}: `{}`",
                        f.name,
                        line.trim().chars().take(80).collect::<String>()
                    ),
                ));
            }
        }
    }
    out
}

/// Serving passes must have an empty lock summary (direct + transitive).
pub fn serving_findings(
    files: &[SourceFile],
    analysis: &LockAnalysis,
    cfg: &Config,
) -> Vec<Finding> {
    let mut out = Vec::new();
    if cfg.serving_file.is_empty() {
        return out;
    }
    for sf in files {
        if sf.rel != cfg.serving_file {
            continue;
        }
        for f in &sf.fns {
            if !cfg.serving_fns.iter().any(|n| n == &f.name) {
                continue;
            }
            let key = FnKey {
                file: sf.rel.clone(),
                name: f.name.clone(),
                start_line: f.start_line,
            };
            if let Some(locks) = analysis.summaries.get(&key) {
                for lock in locks {
                    out.push(Finding::new(
                        "serving-lock",
                        format!("{}:{}", f.name, lock),
                        &sf.rel,
                        f.start_line,
                        format!(
                            "serving pass {} may block on lock {} \
                             (directly or via a callee)",
                            f.name, lock
                        ),
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_patterns() {
        assert!(panic_on_line("let x = v.pop().unwrap();"));
        assert!(panic_on_line("res.expect(\"always\");"));
        assert!(panic_on_line("panic!(\"boom\");"));
        assert!(panic_on_line("unreachable!()"));
        assert!(!panic_on_line("let unwrap = 3;"));
        assert!(!panic_on_line("self.unwrap_or_default();"));
        assert!(!panic_on_line("fn expect_header() {}"));
    }

    #[test]
    fn index_patterns() {
        assert!(index_on_line("let x = buf[0];"));
        assert!(index_on_line("let y = self.table[i + 1];"));
        assert!(!index_on_line("let v = vec![1, 2];"));
        assert!(!index_on_line("#[derive(Debug)]"));
        assert!(!index_on_line("fn f(b: [u8; 4]) {}"));
        assert!(!index_on_line("let z: &[u8] = &b;"));
    }
}
