//! Analyzer configuration: which tree to scan and which files/functions
//! sit in each enforcement cone. `Config::for_tree` is the real sqemu
//! layout; fixture tests build custom configs pointing at small trees.

use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct Config {
    /// Directory scanned for `.rs` files (normally `<repo>/rust/src`).
    pub src_dir: PathBuf,
    /// Checked-in lock hierarchy (`<rank> <lock>` lines). When set, every
    /// lock must be ranked and every observed nesting must descend.
    pub lock_order: Option<PathBuf>,
    /// Checked-in exceptions (`<rule> <key> -- reason` lines). Unused
    /// entries are themselves findings, so exceptions cannot go stale.
    pub allowlist: Option<PathBuf>,
    /// Files (relative to `src_dir`) whose entire non-test code must be
    /// free of panic paths (`unwrap`/`expect`/`panic!`/...).
    pub panic_files: Vec<String>,
    /// Files whose non-test code must not use `[]` indexing.
    pub index_files: Vec<String>,
    /// (file, fn-name-prefix) pairs: named functions join the panic cone.
    pub panic_fn_prefixes: Vec<(String, String)>,
    /// File holding the shard-executor serving passes.
    pub serving_file: String,
    /// Serving-pass functions that must not acquire any lock, directly
    /// or transitively.
    pub serving_fns: Vec<String>,
    /// Directory prefixes where durability annotations are enforced.
    pub dur_dirs: Vec<String>,
}

impl Config {
    /// Configuration for the real sqemu tree rooted at `root`.
    pub fn for_tree(root: &Path) -> Config {
        Config {
            src_dir: root.join("rust/src"),
            lock_order: Some(root.join("tools/sqemu-lint/lock-order.txt")),
            allowlist: Some(root.join("tools/sqemu-lint/allowlist.txt")),
            panic_files: vec![
                "control/statestore.rs".to_string(),
                "control/record.rs".to_string(),
                "qcow/qcheck.rs".to_string(),
            ],
            index_files: vec![
                "control/statestore.rs".to_string(),
                "control/record.rs".to_string(),
            ],
            panic_fn_prefixes: vec![(
                "coordinator/server.rs".to_string(),
                "recover".to_string(),
            )],
            serving_file: "coordinator/shard.rs".to_string(),
            serving_fns: vec![
                "serve_slot".to_string(),
                "serve_reads".to_string(),
                "serve_writes".to_string(),
                "run_batch".to_string(),
            ],
            dur_dirs: vec![
                "coordinator/".to_string(),
                "control/".to_string(),
                "migrate/".to_string(),
            ],
        }
    }

    /// Bare configuration for a fixture tree: no hierarchy, no allowlist,
    /// no cones. Tests opt into the pieces they exercise.
    pub fn bare(src_dir: PathBuf) -> Config {
        Config {
            src_dir,
            lock_order: None,
            allowlist: None,
            panic_files: Vec::new(),
            index_files: Vec::new(),
            panic_fn_prefixes: Vec::new(),
            serving_file: String::new(),
            serving_fns: Vec::new(),
            dur_dirs: Vec::new(),
        }
    }
}
