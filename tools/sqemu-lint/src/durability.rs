//! Durability-ordering lints.
//!
//! Control-plane and migration code must make its journal/mutation
//! ordering explicit with `// lint:` annotations, verified structurally:
//!
//! * `durable-before(t)` — the journal write on this line precedes the
//!   in-memory mutation tagged `mutates(t)` (or `index-flip(t)`) later
//!   in the same function.
//! * `durable-after(t)`  — deliberately journal-after (or best-effort);
//!   standalone.
//! * `durable-rollback(t)` — mutation-first with compensating rollback:
//!   needs an earlier `mutates(t)` and a later `rolls-back(t)`.
//! * `mutates(t)` / `rolls-back(t)` — the paired mutation sites.
//! * `index-flip(t)` — an atomic pointer/index flip making state live;
//!   every write since the previous flush must be fenced before it.
//!
//! Calls that persist state (`.persist(`, `.journal.commit(`, ...) in
//! the durability directories are required to carry one of the matching
//! annotations, so new journal writes cannot land unclassified.

use crate::config::Config;
use crate::report::Finding;
use crate::scan::{find_words, skip_ws, skip_ws_back, word_at, SourceFile};
use std::collections::HashMap;

use crate::cones::dot_call;

/// `.first.second(` with whitespace tolerance.
fn chain_dot_call(line: &[u8], first: &str, second: &str) -> bool {
    for p in find_words(line, second) {
        let after = skip_ws(line, p + second.len());
        if after >= line.len() || line[after] != b'(' {
            continue;
        }
        let b = skip_ws_back(line, p);
        if b == 0 || line[b - 1] != b'.' {
            continue;
        }
        let c = skip_ws_back(line, b - 1);
        if c < first.len() || !word_at(line, c - first.len(), first) {
            continue;
        }
        let d = skip_ws_back(line, c - first.len());
        if d > 0 && line[d - 1] == b'.' {
            return true;
        }
    }
    false
}

/// `name(` as a free/assoc call (no dot required before the name).
fn bare_call(line: &[u8], name: &str) -> bool {
    for p in find_words(line, name) {
        let after = skip_ws(line, p + name.len());
        if after < line.len() && line[after] == b'(' {
            return true;
        }
    }
    false
}

fn write_on_line(line: &[u8]) -> bool {
    dot_call(line, "write_at") || dot_call(line, "append") || dot_call(line, "commit")
}

fn flush_on_line(line: &[u8]) -> bool {
    dot_call(line, "flush") || dot_call(line, "commit")
}

/// Annotation sets that satisfy each persistence pattern.
fn required_annotations(line: &[u8]) -> Vec<&'static [&'static str]> {
    let mut out: Vec<&'static [&'static str]> = Vec::new();
    if dot_call(line, "persist") {
        out.push(&["durable-before", "durable-after", "durable-rollback"]);
    }
    if dot_call(line, "persist_best_effort") {
        out.push(&["durable-after"]);
    }
    if dot_call(line, "append_unfenced") {
        out.push(&["durable-after"]);
    }
    if chain_dot_call(line, "journal", "commit") {
        out.push(&["durable-before", "durable-after"]);
    }
    out
}

/// Is this line an index flip that must be annotated, per directory?
fn flip_on_line(rel: &str, line: &[u8]) -> bool {
    (rel.starts_with("migrate/") && bare_call(line, "commit_migration"))
        || (rel.starts_with("control/") && chain_dot_call(line, "ptr", "write_at"))
}

pub fn durability_findings(sf: &SourceFile, cfg: &Config) -> Vec<Finding> {
    let mut out = Vec::new();
    if !cfg.dur_dirs.iter().any(|d| sf.rel.starts_with(d.as_str())) {
        return out;
    }
    for f in &sf.fns {
        if sf.in_test(f.start_line) {
            continue;
        }
        let key = format!("{}:{}", sf.rel, f.name);
        // Annotations by tag within this fn: name -> [(line, arg)].
        let mut tags: HashMap<&str, Vec<(usize, &str)>> = HashMap::new();
        for ln in f.start_line..=f.end_line {
            if let Some(anns) = sf.annotations.get(&ln) {
                for (nm, arg) in anns {
                    tags.entry(nm.as_str()).or_default().push((ln, arg.as_str()));
                }
            }
        }
        let lines_of = |nm: &str, arg: &str| -> Vec<usize> {
            tags.get(nm)
                .map(|v| {
                    v.iter()
                        .filter(|(_, a)| *a == arg)
                        .map(|(l, _)| *l)
                        .collect()
                })
                .unwrap_or_default()
        };

        for idx in f.start_line - 1..f.end_line.min(sf.code_lines.len()) {
            let ln = idx + 1;
            let line = sf.code_lines[idx].as_bytes();
            let anns: Vec<&str> = sf
                .annotations
                .get(&ln)
                .map(|v| v.iter().map(|(n, _)| n.as_str()).collect())
                .unwrap_or_default();
            for need in required_annotations(line) {
                if !need.iter().any(|n| anns.contains(n)) {
                    out.push(Finding::new(
                        "durability-unannotated",
                        key.clone(),
                        &sf.rel,
                        ln,
                        format!(
                            "persistence call in {} lacks a durability \
                             annotation (one of: {})",
                            f.name,
                            need.join(", ")
                        ),
                    ));
                }
            }
            if flip_on_line(&sf.rel, line) && !anns.contains(&"index-flip") {
                out.push(Finding::new(
                    "durability-flip-unflagged",
                    key.clone(),
                    &sf.rel,
                    ln,
                    format!(
                        "index/pointer flip in {} lacks `lint: index-flip(..)`",
                        f.name
                    ),
                ));
            }
        }

        // Pairing checks.
        for (ln, arg) in tags.get("durable-before").cloned().unwrap_or_default() {
            let later = lines_of("mutates", arg)
                .into_iter()
                .chain(lines_of("index-flip", arg))
                .any(|l| l > ln);
            if !later {
                out.push(Finding::new(
                    "durability-unpaired",
                    key.clone(),
                    &sf.rel,
                    ln,
                    format!(
                        "durable-before({arg}) has no later mutates({arg}) \
                         or index-flip({arg}) in {}",
                        f.name
                    ),
                ));
            }
        }
        for (ln, arg) in tags.get("durable-rollback").cloned().unwrap_or_default() {
            if !lines_of("mutates", arg).into_iter().any(|l| l < ln) {
                out.push(Finding::new(
                    "durability-unpaired",
                    key.clone(),
                    &sf.rel,
                    ln,
                    format!(
                        "durable-rollback({arg}) needs an earlier \
                         mutates({arg}) in {}",
                        f.name
                    ),
                ));
            }
            if !lines_of("rolls-back", arg).into_iter().any(|l| l > ln) {
                out.push(Finding::new(
                    "durability-unpaired",
                    key.clone(),
                    &sf.rel,
                    ln,
                    format!(
                        "durable-rollback({arg}) needs a later \
                         rolls-back({arg}) in {}",
                        f.name
                    ),
                ));
            }
        }
        for (ln, arg) in tags.get("mutates").cloned().unwrap_or_default() {
            let ok = lines_of("durable-before", arg).into_iter().any(|l| l < ln)
                || lines_of("durable-rollback", arg).into_iter().any(|l| l > ln);
            if !ok {
                out.push(Finding::new(
                    "durability-unpaired",
                    key.clone(),
                    &sf.rel,
                    ln,
                    format!(
                        "mutates({arg}) has no earlier durable-before({arg}) \
                         nor later durable-rollback({arg}) in {}",
                        f.name
                    ),
                ));
            }
        }
        for (ln, arg) in tags.get("rolls-back").cloned().unwrap_or_default() {
            if !lines_of("durable-rollback", arg).into_iter().any(|l| l < ln) {
                out.push(Finding::new(
                    "durability-unpaired",
                    key.clone(),
                    &sf.rel,
                    ln,
                    format!(
                        "rolls-back({arg}) has no earlier \
                         durable-rollback({arg}) in {}",
                        f.name
                    ),
                ));
            }
        }

        // Flush-before-flip: every journal write since the last fence
        // must be flushed before the flip makes state reachable.
        for (ln, arg) in tags.get("index-flip").cloned().unwrap_or_default() {
            let mut last_write = None;
            for idx in f.start_line - 1..(ln - 1).min(sf.code_lines.len()) {
                if write_on_line(sf.code_lines[idx].as_bytes()) {
                    last_write = Some(idx + 1);
                }
            }
            let Some(last_write) = last_write else { continue };
            let fenced = (last_write..ln)
                .any(|l| flush_on_line(sf.code_lines[l - 1].as_bytes()));
            if !fenced {
                out.push(Finding::new(
                    "durability-missing-flush",
                    key.clone(),
                    &sf.rel,
                    ln,
                    format!(
                        "index-flip({arg}) in {}: journal write at line \
                         {last_write} is not flushed before the flip",
                        f.name
                    ),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use std::path::PathBuf;

    fn cfg_all() -> Config {
        let mut c = Config::bare(PathBuf::new());
        c.dur_dirs = vec![String::new()]; // match every file
        c
    }

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let sf = SourceFile::parse(rel, src.as_bytes());
        durability_findings(&sf, &cfg_all())
    }

    #[test]
    fn persist_requires_annotation() {
        let f = findings(
            "control/x.rs",
            "fn f(s: &Store) {\n    s.persist(&rec);\n}\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "durability-unannotated");
    }

    #[test]
    fn annotated_pair_passes() {
        let f = findings(
            "control/x.rs",
            "fn f(s: &mut Store) {\n\
             \x20   // lint: durable-before(rec)\n\
             \x20   s.persist(&rec);\n\
             \x20   // lint: mutates(rec)\n\
             \x20   s.view.apply(&rec);\n\
             }\n",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn unpaired_before_fires() {
        let f = findings(
            "control/x.rs",
            "fn f(s: &mut Store) {\n\
             \x20   // lint: durable-before(rec)\n\
             \x20   s.persist(&rec);\n\
             }\n",
        );
        assert!(f.iter().any(|x| x.rule == "durability-unpaired"), "{f:?}");
    }

    #[test]
    fn missing_flush_before_flip_fires() {
        let f = findings(
            "control/x.rs",
            "fn f(s: &mut Store) {\n\
             \x20   s.log.write_at(0, &buf)?;\n\
             \x20   // lint: index-flip(gen)\n\
             \x20   s.ptr.write_at(8, &word)?;\n\
             }\n",
        );
        assert!(
            f.iter().any(|x| x.rule == "durability-missing-flush"),
            "{f:?}"
        );
    }

    #[test]
    fn flush_fences_the_flip() {
        let f = findings(
            "control/x.rs",
            "fn f(s: &mut Store) {\n\
             \x20   s.log.write_at(0, &buf)?;\n\
             \x20   s.log.flush()?;\n\
             \x20   // lint: index-flip(gen)\n\
             \x20   s.ptr.write_at(8, &word)?;\n\
             }\n",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn unflagged_flip_fires() {
        let f = findings(
            "migrate/m.rs",
            "fn f(n: &Nodes) {\n    n.commit_migration(&names, tgt)?;\n}\n",
        );
        assert!(
            f.iter().any(|x| x.rule == "durability-flip-unflagged"),
            "{f:?}"
        );
    }
}
