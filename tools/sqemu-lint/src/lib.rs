//! sqemu-lint: fleet invariant analyzer for the sqemu tree.
//!
//! Source-level static analysis over `rust/src/**` enforcing three
//! invariant families (DESIGN.md §16):
//!
//! 1. **Lock order** — extract every `Mutex`/`RwLock` field and every
//!    nested acquisition (directly or through one level of call
//!    summaries), then require the graph to be acyclic and consistent
//!    with the checked-in hierarchy in `lock-order.txt`.
//! 2. **Durability ordering** — journal writes in `coordinator/`,
//!    `control/` and `migrate/` must carry `// lint: durable-*`
//!    annotations whose pairing (write-ahead vs mutate, flush vs index
//!    flip) is verified structurally.
//! 3. **Cones** — no panic paths or slice indexing in the recovery/
//!    replay cone, and no blocking locks in shard-executor serving
//!    passes.
//!
//! Exceptions live in `allowlist.txt` and must each match a live
//! finding; a stale entry is itself a finding.

pub mod cones;
pub mod config;
pub mod durability;
pub mod lockgraph;
pub mod report;
pub mod scan;

pub use config::Config;
pub use report::{Finding, Report};

use anyhow::Context as _;
use scan::SourceFile;
use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let mut entries: Vec<fs::DirEntry> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

#[derive(Debug)]
struct AllowEntry {
    rule: String,
    key: String,
    line: usize,
    used: bool,
}

/// Parse `allowlist.txt`: `<rule> <key> -- <justification>` per line.
fn parse_allowlist(text: &str) -> anyhow::Result<Vec<AllowEntry>> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((head, reason)) = line.split_once(" -- ") else {
            anyhow::bail!(
                "allowlist.txt:{}: entry needs a ` -- <justification>`",
                idx + 1
            );
        };
        if reason.trim().is_empty() {
            anyhow::bail!("allowlist.txt:{}: empty justification", idx + 1);
        }
        let mut parts = head.split_whitespace();
        let (Some(rule), Some(key), None) =
            (parts.next(), parts.next(), parts.next())
        else {
            anyhow::bail!(
                "allowlist.txt:{}: expected `<rule> <key> -- reason`",
                idx + 1
            );
        };
        out.push(AllowEntry {
            rule: rule.to_string(),
            key: key.to_string(),
            line: idx + 1,
            used: false,
        });
    }
    Ok(out)
}

/// Run the full analysis for `cfg` and return the report.
pub fn run_with(cfg: &Config) -> anyhow::Result<Report> {
    let mut paths = Vec::new();
    collect_rs(&cfg.src_dir, &mut paths)?;
    let mut sources = Vec::new();
    for p in &paths {
        let rel = p
            .strip_prefix(&cfg.src_dir)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            fs::read(p).with_context(|| format!("reading {}", p.display()))?;
        sources.push(SourceFile::parse(&rel, &text));
    }

    let analysis = lockgraph::analyze(&sources);
    let mut findings: Vec<Finding> = Vec::new();

    if let Some(cyc) = lockgraph::find_cycle(&analysis.edges) {
        findings.push(Finding::new(
            "lock-cycle",
            cyc.join("->"),
            "",
            0,
            format!("lock acquisition cycle: {}", cyc.join(" -> ")),
        ));
    }

    let all_locks: BTreeSet<String> = sources
        .iter()
        .flat_map(|sf| {
            sf.lock_fields
                .keys()
                .map(|f| format!("{}.{}", sf.module, f))
                .collect::<Vec<_>>()
        })
        .collect();

    if let Some(order_path) = &cfg.lock_order {
        let text = fs::read_to_string(order_path)
            .with_context(|| format!("reading {}", order_path.display()))?;
        let order = lockgraph::parse_lock_order(&text)?;
        let display = order_path.to_string_lossy().into_owned();
        findings.extend(lockgraph::hierarchy_findings(
            &order,
            &display,
            &all_locks,
            &analysis.edges,
        ));
    }

    for sf in &sources {
        findings.extend(cones::cone_findings(sf, cfg));
    }
    findings.extend(cones::serving_findings(&sources, &analysis, cfg));
    for sf in &sources {
        findings.extend(durability::durability_findings(sf, cfg));
    }

    let mut allow: Vec<AllowEntry> = match &cfg.allowlist {
        Some(p) if p.exists() => {
            let text = fs::read_to_string(p)
                .with_context(|| format!("reading {}", p.display()))?;
            parse_allowlist(&text)?
        }
        _ => Vec::new(),
    };

    let mut kept = Vec::new();
    let mut suppressed = Vec::new();
    for f in findings {
        let hit = allow
            .iter_mut()
            .find(|e| e.rule == f.rule && e.key == f.key);
        match hit {
            Some(e) => {
                e.used = true;
                suppressed.push(f);
            }
            None => kept.push(f),
        }
    }
    let allow_display = cfg
        .allowlist
        .as_ref()
        .map(|p| p.to_string_lossy().into_owned())
        .unwrap_or_default();
    for e in &allow {
        if !e.used {
            kept.push(Finding::new(
                "allowlist-stale",
                format!("{} {}", e.rule, e.key),
                &allow_display,
                e.line,
                format!(
                    "allowlist entry `{} {}` matches no live finding; \
                     remove it",
                    e.rule, e.key
                ),
            ));
        }
    }

    Ok(Report {
        findings: kept,
        suppressed,
        stats: report::Stats {
            files: sources.len(),
            fns: analysis.total_fns,
            locks: all_locks.len(),
            edges: analysis.edges.len(),
            unresolved_acquisitions: analysis.unresolved,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_parsing() {
        let text = "# comment\n\nserving-lock serve_slot:x.y -- reason here\n";
        let entries = parse_allowlist(text).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].rule, "serving-lock");
        assert_eq!(entries[0].key, "serve_slot:x.y");
        assert_eq!(entries[0].line, 3);
        assert!(parse_allowlist("bad entry no reason\n").is_err());
        assert!(parse_allowlist("rule key extra -- r\n").is_err());
    }
}
