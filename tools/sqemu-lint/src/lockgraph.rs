//! Lock-acquisition graph extraction and checking.
//!
//! The analysis is intra-procedural with one level of summarization:
//! each function gets the set of canonical locks (`module.field`) it may
//! acquire, propagated to callers through a fixpoint over a
//! conservatively-resolved call graph. Nested acquisitions become edges
//! `held -> acquired`; the checked-in hierarchy (`lock-order.txt`) must
//! then be a topological order of the observed graph.
//!
//! Guard lifetimes follow rustc's rules closely enough for lint
//! purposes: a `let`-bound guard lives to the end of its enclosing
//! block, a scrutinee/condition guard lives through the block it opens,
//! and any other temporary dies at its statement's semicolon. `drop(g)`
//! releases early.

use crate::report::Finding;
use crate::scan::{
    count_newlines, find_words, ident_at, is_ident, skip_ws, skip_ws_back,
    word_at, SourceFile,
};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

/// Stable identity of a top-level function: file, name, starting line.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FnKey {
    pub file: String,
    pub name: String,
    pub start_line: usize,
}

#[derive(Debug, Clone)]
pub struct Edge {
    pub frm: String,
    pub to: String,
    pub file: String,
    pub line: usize,
    /// Callee name when the inner acquisition happens transitively.
    pub via: Option<String>,
}

#[derive(Debug, Default)]
pub struct FnEvents {
    /// (canonical lock, line) for each acquisition in this body.
    pub acquisitions: Vec<(String, usize)>,
    /// Direct nesting edges observed inside this body.
    pub edges: Vec<Edge>,
    /// (callee, locks held at the call, line).
    pub calls: Vec<(FnKey, Vec<String>, usize)>,
    pub unresolved: usize,
}

pub struct LockAnalysis {
    /// Deduped by (from, to); first observation wins.
    pub edges: Vec<Edge>,
    /// Fixpoint lock summaries (direct + transitive) per function.
    pub summaries: HashMap<FnKey, BTreeSet<String>>,
    pub unresolved: usize,
    pub total_fns: usize,
}

/// Control-flow / std names that look like calls but never resolve.
const KEYWORDS: &[&str] = &[
    "if", "while", "match", "for", "loop", "return", "fn", "let", "move",
    "in", "as", "else", "Some", "Ok", "Err", "None", "Box", "Arc", "Vec",
    "String", "assert", "debug_assert",
];

/// Method names std/collections also provide: a receiver-qualified call
/// or a global-unique fallback must never resolve these to a tree
/// function of the same name (same-file `self.x()` still resolves).
const STOPLIST: &[&str] = &[
    "clear", "insert", "remove", "get", "get_mut", "len", "is_empty",
    "push", "pop", "iter", "iter_mut", "drain", "entry", "contains",
    "contains_key", "extend", "take", "replace", "send", "recv", "clone",
    "lock", "read", "write", "flush", "wait", "wait_timeout", "notify",
    "notify_all", "notify_one", "join", "spawn", "store", "load", "swap",
    "fetch_add", "fetch_max", "compare_exchange", "next", "last", "first",
    "count", "find", "position", "retain", "abs", "min", "max", "new",
    "default", "with_capacity",
];

#[derive(Debug, Clone, Copy, PartialEq)]
enum AcqKind {
    Unpoisoned,
    Lock,
    Read,
    Write,
}

#[derive(Debug)]
struct Acq {
    start: usize,
    end: usize,
    recv: String,
    kind: AcqKind,
}

#[derive(Debug, PartialEq)]
enum Recv {
    Bare,
    SelfOnly,
    Qualified,
}

#[derive(Debug)]
struct Call {
    start: usize,
    name: String,
    recv: Recv,
}

/// Receiver-chain byte: `[A-Za-z0-9_.\[\]]`.
fn is_chain(b: u8) -> bool {
    is_ident(b) || b == b'.' || b == b'[' || b == b']'
}

/// `mut` keyword followed by whitespace; returns the post-ws offset.
fn eat_mut(s: &[u8], i: usize) -> usize {
    if word_at(s, i, "mut") {
        let j = skip_ws(s, i + 3);
        if j > i + 3 {
            return j;
        }
    }
    i
}

/// All lock acquisitions in a flattened segment, in source order.
/// Matches `lock_unpoisoned(&self.field)` and `chain.lock()` /
/// `chain.read()` / `chain.write()` (empty argument lists only).
fn acq_matches(flat: &[u8]) -> Vec<Acq> {
    let mut out: Vec<Acq> = Vec::new();
    for p in find_words(flat, "lock_unpoisoned") {
        let open = p + "lock_unpoisoned".len();
        if open >= flat.len() || flat[open] != b'(' {
            continue;
        }
        let mut i = skip_ws(flat, open + 1);
        if i < flat.len() && flat[i] == b'&' {
            i = skip_ws(flat, i + 1);
        }
        i = eat_mut(flat, i);
        let start_cap = i;
        let mut k = i;
        while k < flat.len() && (is_chain(flat[k]) || flat[k].is_ascii_whitespace())
        {
            k += 1;
        }
        if k >= flat.len() || flat[k] != b')' {
            continue;
        }
        let recv = String::from_utf8_lossy(&flat[start_cap..k])
            .trim()
            .to_string();
        if recv.is_empty() {
            continue;
        }
        out.push(Acq {
            start: p,
            end: k + 1,
            recv,
            kind: AcqKind::Unpoisoned,
        });
    }
    for (method, kind) in [
        ("lock", AcqKind::Lock),
        ("read", AcqKind::Read),
        ("write", AcqKind::Write),
    ] {
        for p in find_words(flat, method) {
            let after = skip_ws(flat, p + method.len());
            if after >= flat.len() || flat[after] != b'(' {
                continue;
            }
            let close = skip_ws(flat, after + 1);
            if close >= flat.len() || flat[close] != b')' {
                continue;
            }
            let b = skip_ws_back(flat, p);
            if b == 0 || flat[b - 1] != b'.' {
                continue;
            }
            let c = skip_ws_back(flat, b - 1);
            let mut d = c;
            while d > 0 && is_chain(flat[d - 1]) {
                d -= 1;
            }
            if d == c {
                continue;
            }
            out.push(Acq {
                start: d,
                end: close + 1,
                recv: String::from_utf8_lossy(&flat[d..c]).into_owned(),
                kind,
            });
        }
    }
    out.sort_by_key(|a| a.start);
    let mut merged: Vec<Acq> = Vec::new();
    for a in out {
        let overlaps = match merged.last() {
            Some(prev) => a.start < prev.end,
            None => false,
        };
        if !overlaps {
            merged.push(a);
        }
    }
    merged
}

/// All call sites `name(`, `self.name(`, `recv.name(` in a segment.
fn call_matches(flat: &[u8]) -> Vec<Call> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < flat.len() {
        if !is_ident(flat[i]) || (i > 0 && is_ident(flat[i - 1])) {
            i += 1;
            continue;
        }
        let Some((j, name)) = ident_at(flat, i) else {
            i += 1;
            continue;
        };
        let k = skip_ws(flat, j);
        if k >= flat.len() || flat[k] != b'(' {
            i = j;
            continue;
        }
        let b = skip_ws_back(flat, i);
        let (recv, start) = if b > 0 && flat[b - 1] == b'.' {
            let c = skip_ws_back(flat, b - 1);
            let mut d = c;
            while d > 0 && is_chain(flat[d - 1]) {
                d -= 1;
            }
            if d == c {
                (Recv::Bare, i)
            } else if &flat[d..c] == b"self" {
                (Recv::SelfOnly, d)
            } else {
                (Recv::Qualified, d)
            }
        } else {
            (Recv::Bare, i)
        };
        out.push(Call { start, name, recv });
        i = j;
    }
    out
}

/// `^\s*let\s+(mut\s+)?NAME\s*(:[^=]+)?=` — the variable a statement
/// binds, used to decide whether an acquisition outlives its statement.
fn let_binding(flat: &[u8]) -> Option<String> {
    let i = skip_ws(flat, 0);
    if !word_at(flat, i, "let") {
        return None;
    }
    let mut j = skip_ws(flat, i + 3);
    if j == i + 3 {
        return None;
    }
    j = eat_mut(flat, j);
    let (end, name) = ident_at(flat, j)?;
    let mut m = skip_ws(flat, end);
    if m < flat.len() && flat[m] == b':' {
        m += 1;
        let start = m;
        while m < flat.len() && flat[m] != b'=' {
            m += 1;
        }
        if m == start {
            return None;
        }
    }
    if m < flat.len() && flat[m] == b'=' {
        Some(name)
    } else {
        None
    }
}

/// `let g = &self.field` at offset `p` of the `let`; returns (var, field).
fn parse_alias_let(flat: &[u8], p: usize) -> Option<(String, String)> {
    let mut i = skip_ws(flat, p + 3);
    if i == p + 3 {
        return None;
    }
    i = eat_mut(flat, i);
    let (end, var) = ident_at(flat, i)?;
    let mut j = skip_ws(flat, end);
    if j >= flat.len() || flat[j] != b'=' {
        return None;
    }
    j = skip_ws(flat, j + 1);
    if j < flat.len() && flat[j] == b'&' {
        j = skip_ws(flat, j + 1);
    }
    j = eat_mut(flat, j);
    if !word_at(flat, j, "self") {
        return None;
    }
    j = skip_ws(flat, j + 4);
    if j >= flat.len() || flat[j] != b'.' {
        return None;
    }
    j = skip_ws(flat, j + 1);
    let (_, field) = ident_at(flat, j)?;
    Some((var, field))
}

/// `for v in &self.field` at offset `p` of the `for`.
fn parse_alias_for(flat: &[u8], p: usize) -> Option<(String, String)> {
    let i = skip_ws(flat, p + 3);
    if i == p + 3 {
        return None;
    }
    let (end, var) = ident_at(flat, i)?;
    let j = skip_ws(flat, end);
    if j == end || !word_at(flat, j, "in") {
        return None;
    }
    let mut k = skip_ws(flat, j + 2);
    if k == j + 2 {
        return None;
    }
    if k < flat.len() && flat[k] == b'&' {
        k = skip_ws(flat, k + 1);
    }
    if !word_at(flat, k, "self") {
        return None;
    }
    k = skip_ws(flat, k + 4);
    if k >= flat.len() || flat[k] != b'.' {
        return None;
    }
    k = skip_ws(flat, k + 1);
    let (_, field) = ident_at(flat, k)?;
    Some((var, field))
}

/// `self.field.iter()...|v|` at offset `p` of the `self`.
fn parse_alias_iter(flat: &[u8], p: usize) -> Option<(String, String)> {
    let mut i = skip_ws(flat, p + 4);
    if i >= flat.len() || flat[i] != b'.' {
        return None;
    }
    i = skip_ws(flat, i + 1);
    let (e1, field) = ident_at(flat, i)?;
    let mut j = skip_ws(flat, e1);
    if j >= flat.len() || flat[j] != b'.' {
        return None;
    }
    j = skip_ws(flat, j + 1);
    if !word_at(flat, j, "iter") || j + 6 > flat.len() || &flat[j + 4..j + 6] != b"()"
    {
        return None;
    }
    let mut k = j + 6;
    while k < flat.len() && flat[k] != b'|' {
        k += 1;
    }
    if k >= flat.len() {
        return None;
    }
    k = skip_ws(flat, k + 1);
    k = eat_mut(flat, k);
    let (e2, var) = ident_at(flat, k)?;
    let m = skip_ws(flat, e2);
    if m >= flat.len() || flat[m] != b'|' {
        return None;
    }
    Some((var, field))
}

/// Aliases that make a later `guard.lock()` resolvable to a field:
/// `let g = &self.field;`, `for f in &self.files`, and
/// `self.files.iter()...|f| ...`.
fn collect_aliases(
    flat: &[u8],
    sf: &SourceFile,
    aliases: &mut HashMap<String, String>,
) {
    let kinds: [(&str, fn(&[u8], usize) -> Option<(String, String)>); 3] = [
        ("let", parse_alias_let),
        ("for", parse_alias_for),
        ("self", parse_alias_iter),
    ];
    for (word, parse) in kinds {
        for p in find_words(flat, word) {
            if let Some((var, field)) = parse(flat, p) {
                if sf.lock_fields.contains_key(&field) {
                    aliases.insert(var, field);
                }
            }
        }
    }
}

/// Variables released early via `drop(var)`.
fn drop_vars(flat: &[u8]) -> Vec<String> {
    let mut out = Vec::new();
    for p in find_words(flat, "drop") {
        let i = skip_ws(flat, p + 4);
        if i >= flat.len() || flat[i] != b'(' {
            continue;
        }
        let j = skip_ws(flat, i + 1);
        let Some((end, var)) = ident_at(flat, j) else { continue };
        let k = skip_ws(flat, end);
        if k < flat.len() && flat[k] == b')' {
            out.push(var);
        }
    }
    out
}

/// True when the rest of the statement after an acquisition is only
/// `.unwrap()` / `.expect(..)` / `.unwrap_or_else(..)` chains — i.e.
/// the `let` really binds the guard, not something derived from it.
fn allowed_suffix(s: &[u8]) -> bool {
    let scan_no_parens = |s: &[u8], mut b: usize| -> usize {
        while b < s.len() && s[b] != b'(' && s[b] != b')' {
            b += 1;
        }
        b
    };
    let mut i = 0usize;
    loop {
        let save = i;
        let j = skip_ws(s, i);
        let mut matched = false;
        if j < s.len() && s[j] == b'.' {
            let k = skip_ws(s, j + 1);
            if word_at(s, k, "unwrap") {
                let a = skip_ws(s, k + 6);
                if a < s.len() && s[a] == b'(' {
                    let b = skip_ws(s, a + 1);
                    if b < s.len() && s[b] == b')' {
                        i = b + 1;
                        matched = true;
                    }
                }
            } else if word_at(s, k, "expect") {
                let a = skip_ws(s, k + 6);
                if a < s.len() && s[a] == b'(' {
                    let b = scan_no_parens(s, a + 1);
                    if b < s.len() && s[b] == b')' {
                        i = b + 1;
                        matched = true;
                    }
                }
            } else if word_at(s, k, "unwrap_or_else") {
                let a = skip_ws(s, k + 14);
                if a < s.len() && s[a] == b'(' {
                    let mut b = scan_no_parens(s, a + 1);
                    if b < s.len() && s[b] == b'(' {
                        b = scan_no_parens(s, b + 1);
                        if b < s.len() && s[b] == b')' {
                            b = scan_no_parens(s, b + 1);
                        }
                    }
                    if b < s.len() && s[b] == b')' {
                        i = b + 1;
                        matched = true;
                    }
                }
            }
        }
        if !matched {
            i = save;
            break;
        }
    }
    let j = skip_ws(s, i);
    let j = if j < s.len() && s[j] == b';' {
        skip_ws(s, j + 1)
    } else {
        j
    };
    j == s.len()
}

/// Remove innermost `[...]` groups (applied twice for one nesting level).
fn strip_bracket_groups_once(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == b'[' {
            let mut j = i + 1;
            let mut close = None;
            while j < b.len() {
                if b[j] == b'[' {
                    break;
                }
                if b[j] == b']' {
                    close = Some(j);
                    break;
                }
                j += 1;
            }
            if let Some(j) = close {
                i = j + 1;
                continue;
            }
        }
        out.push(b[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Map an acquisition receiver to a lock field of this file's struct:
/// `self.field`, a `let`/`for`/closure alias of one, or a bare field
/// name. Index expressions (`self.shards[i]`) are stripped first.
fn resolve_receiver(
    recv: &str,
    aliases: &HashMap<String, String>,
    sf: &SourceFile,
) -> Option<String> {
    let r = recv.trim().trim_start_matches(['&', '*']).trim().to_string();
    let r = strip_bracket_groups_once(&r);
    let r = strip_bracket_groups_once(&r);
    let parts: Vec<&str> = r
        .split('.')
        .map(|p| p.trim())
        .filter(|p| !p.is_empty())
        .collect();
    if parts.is_empty() {
        return None;
    }
    if parts[0] == "self" && parts.len() >= 2 {
        let f = parts[1];
        return sf.lock_fields.contains_key(f).then(|| f.to_string());
    }
    if parts.len() == 1 {
        let v = parts[0];
        if let Some(f) = aliases.get(v) {
            return Some(f.clone());
        }
        if sf.lock_fields.contains_key(v) {
            return Some(v.to_string());
        }
    }
    None
}

/// Conservative call resolution. Same-file definitions win for bare and
/// `self.` calls; otherwise only a globally unique name resolves, and a
/// name std types also provide (STOPLIST) never resolves through a
/// receiver or the global-unique fallback.
fn resolve_call(
    name: &str,
    recv: &Recv,
    sf_rel: &str,
    fn_index: &HashMap<String, Vec<FnKey>>,
) -> Option<FnKey> {
    let cands = fn_index.get(name)?;
    if cands.is_empty() {
        return None;
    }
    match recv {
        Recv::Bare | Recv::SelfOnly => {
            if let Some(same) = cands.iter().find(|k| k.file == sf_rel) {
                return Some(same.clone());
            }
            if STOPLIST.contains(&name) {
                return None;
            }
            if cands.len() == 1 {
                return Some(cands[0].clone());
            }
            None
        }
        Recv::Qualified => {
            if STOPLIST.contains(&name) {
                return None;
            }
            if cands.len() == 1 {
                return Some(cands[0].clone());
            }
            None
        }
    }
}

struct Guard {
    canon: String,
    var: Option<String>,
    bind_depth: i64,
}

/// One pass over a function body: segment-at-a-time (segments split at
/// `;` / `{` / `}` at any depth), tracking held guards across segments.
fn walk_fn(
    sf: &SourceFile,
    f: &crate::scan::FnInfo,
    fn_index: &HashMap<String, Vec<FnKey>>,
) -> FnEvents {
    let mut events = FnEvents::default();
    if sf.in_test(f.start_line) {
        return events;
    }
    let body = &sf.code[f.body..=f.end.min(sf.code.len() - 1)];
    let body_line0 = sf.line_of(f.body);
    let canon = |field: &str| format!("{}.{}", sf.module, field);

    let mut aliases: HashMap<String, String> = HashMap::new();
    let mut held: Vec<Guard> = Vec::new();
    let mut depth: i64 = 0;
    let mut seg_start = 0usize;
    let mut seg_nl = 0usize;
    let mut nl = 0usize;
    let n = body.len();
    let mut i = 0usize;
    while i <= n {
        let ch = if i < n { body[i] } else { b';' };
        if ch == b'\n' {
            nl += 1;
            i += 1;
            continue;
        }
        if ch != b';' && ch != b'{' && ch != b'}' {
            i += 1;
            continue;
        }
        let seg = &body[seg_start..i];
        let flat: Vec<u8> = seg
            .iter()
            .map(|&b| if b == b'\n' { b' ' } else { b })
            .collect();
        let seg_line0 = body_line0 + seg_nl;

        collect_aliases(&flat, sf, &mut aliases);
        for var in drop_vars(&flat) {
            held.retain(|h| h.var.as_deref() != Some(var.as_str()));
        }

        let letm = let_binding(&flat);
        let mut seg_temps: Vec<Guard> = Vec::new();
        for acq in acq_matches(&flat) {
            let field = resolve_receiver(&acq.recv, &aliases, sf);
            let Some(field) = field else {
                if matches!(acq.kind, AcqKind::Unpoisoned | AcqKind::Lock) {
                    events.unresolved += 1;
                }
                continue;
            };
            let lk = sf.lock_fields.get(&field).map(String::as_str);
            match acq.kind {
                AcqKind::Read | AcqKind::Write if lk != Some("RwLock") => continue,
                AcqKind::Lock | AcqKind::Unpoisoned if lk == Some("RwLock") => {
                    continue
                }
                _ => {}
            }
            let line = seg_line0 + count_newlines(&seg[..acq.start.min(seg.len())]);
            let c = canon(&field);
            for h in held.iter().chain(seg_temps.iter()) {
                events.edges.push(Edge {
                    frm: h.canon.clone(),
                    to: c.clone(),
                    file: sf.rel.clone(),
                    line,
                    via: None,
                });
            }
            events.acquisitions.push((c.clone(), line));
            if letm.is_some() && allowed_suffix(&flat[acq.end.min(flat.len())..]) {
                held.push(Guard {
                    canon: c,
                    var: letm.clone(),
                    bind_depth: depth,
                });
            } else {
                seg_temps.push(Guard {
                    canon: c,
                    var: None,
                    bind_depth: depth,
                });
            }
        }

        for call in call_matches(&flat) {
            if KEYWORDS.contains(&call.name.as_str()) {
                continue;
            }
            if call.recv == Recv::Bare {
                // skip nested `fn name(..)` definitions
                let e = skip_ws_back(&flat, call.start);
                if e >= 2 && word_at(&flat, e - 2, "fn") {
                    continue;
                }
            }
            let Some(callee) = resolve_call(&call.name, &call.recv, &sf.rel, fn_index)
            else {
                continue;
            };
            let line = seg_line0 + count_newlines(&seg[..call.start.min(seg.len())]);
            let hold_now: Vec<String> = held
                .iter()
                .chain(seg_temps.iter())
                .map(|h| h.canon.clone())
                .collect();
            events.calls.push((callee, hold_now, line));
        }

        match ch {
            b'{' => {
                // Scrutinee / condition guards live through the block.
                for mut t in seg_temps {
                    t.bind_depth = depth + 1;
                    held.push(t);
                }
                depth += 1;
            }
            b'}' => {
                depth -= 1;
                held.retain(|h| h.bind_depth <= depth);
            }
            _ => {} // ';' — seg_temps die here
        }
        seg_start = i + 1;
        seg_nl = nl;
        i += 1;
    }
    events
}

/// Build the full lock analysis for a set of files.
pub fn analyze(files: &[SourceFile]) -> LockAnalysis {
    let mut fn_index: HashMap<String, Vec<FnKey>> = HashMap::new();
    for sf in files {
        for f in &sf.fns {
            if !sf.in_test(f.start_line) {
                fn_index.entry(f.name.clone()).or_default().push(FnKey {
                    file: sf.rel.clone(),
                    name: f.name.clone(),
                    start_line: f.start_line,
                });
            }
        }
    }

    let mut per_fn: Vec<(FnKey, FnEvents)> = Vec::new();
    for sf in files {
        for f in &sf.fns {
            let key = FnKey {
                file: sf.rel.clone(),
                name: f.name.clone(),
                start_line: f.start_line,
            };
            per_fn.push((key, walk_fn(sf, f, &fn_index)));
        }
    }

    let mut summaries: HashMap<FnKey, BTreeSet<String>> = per_fn
        .iter()
        .map(|(k, v)| {
            (
                k.clone(),
                v.acquisitions.iter().map(|(l, _)| l.clone()).collect(),
            )
        })
        .collect();
    loop {
        let mut changed = false;
        for (k, v) in &per_fn {
            for (callee, _held, _ln) in &v.calls {
                let add: Vec<String> = summaries
                    .get(callee)
                    .map(|s| s.iter().cloned().collect())
                    .unwrap_or_default();
                let mine = summaries.get_mut(k).expect("summary exists");
                for l in add {
                    if mine.insert(l) {
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }

    let mut edges: Vec<Edge> = Vec::new();
    for (k, v) in &per_fn {
        edges.extend(v.edges.iter().cloned());
        for (callee, held, ln) in &v.calls {
            if let Some(locks) = summaries.get(callee) {
                for lock in locks {
                    for h in held {
                        edges.push(Edge {
                            frm: h.clone(),
                            to: lock.clone(),
                            file: k.file.clone(),
                            line: *ln,
                            via: Some(callee.name.clone()),
                        });
                    }
                }
            }
        }
    }

    let mut seen: HashSet<(String, String)> = HashSet::new();
    let mut deduped: Vec<Edge> = Vec::new();
    for e in edges {
        if seen.insert((e.frm.clone(), e.to.clone())) {
            deduped.push(e);
        }
    }

    let unresolved = per_fn.iter().map(|(_, v)| v.unresolved).sum();
    LockAnalysis {
        edges: deduped,
        summaries,
        unresolved,
        total_fns: per_fn.len(),
    }
}

/// First cycle in the deduped edge set, as a lock-name path `a -> .. -> a`.
pub fn find_cycle(edges: &[Edge]) -> Option<Vec<String>> {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in edges {
        adj.entry(&e.frm).or_default().insert(&e.to);
    }
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Gray,
        Black,
    }
    fn dfs<'a>(
        u: &'a str,
        adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
        color: &mut HashMap<&'a str, Color>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        color.insert(u, Color::Gray);
        stack.push(u);
        if let Some(next) = adj.get(u) {
            for &v in next {
                match color.get(v).copied().unwrap_or(Color::White) {
                    Color::Gray => {
                        let pos = stack.iter().position(|&x| x == v).unwrap_or(0);
                        let mut cyc: Vec<String> =
                            stack[pos..].iter().map(|s| s.to_string()).collect();
                        cyc.push(v.to_string());
                        return Some(cyc);
                    }
                    Color::White => {
                        if let Some(c) = dfs(v, adj, color, stack) {
                            return Some(c);
                        }
                    }
                    Color::Black => {}
                }
            }
        }
        stack.pop();
        color.insert(u, Color::Black);
        None
    }
    let mut color: HashMap<&str, Color> = HashMap::new();
    let roots: Vec<&str> = adj.keys().copied().collect();
    for u in roots {
        if color.get(u).copied().unwrap_or(Color::White) == Color::White {
            let mut stack = Vec::new();
            if let Some(c) = dfs(u, &adj, &mut color, &mut stack) {
                return Some(c);
            }
        }
    }
    None
}

/// Parse `lock-order.txt`: `<rank> <lock>` per line, `#` comments.
pub fn parse_lock_order(text: &str) -> anyhow::Result<Vec<(String, i64)>> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rank), Some(name)) = (parts.next(), parts.next()) else {
            anyhow::bail!("lock-order.txt:{}: malformed line", idx + 1);
        };
        let rank: i64 = rank.parse().map_err(|_| {
            anyhow::anyhow!("lock-order.txt:{}: bad rank {rank:?}", idx + 1)
        })?;
        if parts.next().is_some() {
            anyhow::bail!("lock-order.txt:{}: trailing tokens", idx + 1);
        }
        out.push((name.to_string(), rank));
    }
    Ok(out)
}

/// Check the observed graph against the checked-in hierarchy.
pub fn hierarchy_findings(
    order: &[(String, i64)],
    order_display: &str,
    all_locks: &BTreeSet<String>,
    edges: &[Edge],
) -> Vec<Finding> {
    let ranks: HashMap<&str, i64> =
        order.iter().map(|(n, r)| (n.as_str(), *r)).collect();
    let mut out = Vec::new();
    for lk in all_locks {
        if !ranks.contains_key(lk.as_str()) {
            out.push(Finding::new(
                "lock-unranked",
                lk.clone(),
                order_display,
                0,
                format!("lock {lk} has no rank in the checked-in hierarchy"),
            ));
        }
    }
    for (name, _) in order {
        if !all_locks.contains(name) {
            out.push(Finding::new(
                "rank-stale",
                name.clone(),
                order_display,
                0,
                format!("ranked lock {name} no longer exists in the tree"),
            ));
        }
    }
    let mut sorted: Vec<&Edge> = edges.iter().collect();
    sorted.sort_by(|a, b| (&a.frm, &a.to).cmp(&(&b.frm, &b.to)));
    for e in sorted {
        let key = format!("{}->{}", e.frm, e.to);
        let via = e
            .via
            .as_ref()
            .map(|v| format!(" via {v}()"))
            .unwrap_or_default();
        if e.frm == e.to {
            out.push(Finding::new(
                "lock-self-edge",
                key,
                &e.file,
                e.line,
                format!("{} re-acquired while already held{via}", e.frm),
            ));
        } else if let (Some(rf), Some(rt)) =
            (ranks.get(e.frm.as_str()), ranks.get(e.to.as_str()))
        {
            if rf >= rt {
                out.push(Finding::new(
                    "lock-order",
                    key,
                    &e.file,
                    e.line,
                    format!(
                        "{}(rank {rf}) acquired before {}(rank {rt}){via}: \
                         violates the lock hierarchy",
                        e.frm, e.to
                    ),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, src: &str) -> SourceFile {
        SourceFile::parse(rel, src.as_bytes())
    }

    #[test]
    fn acq_matcher_forms() {
        let flat = b"let g = self.files.lock().unwrap(); let h = lock_unpoisoned(&self.index); x.read()";
        let acqs = acq_matches(flat);
        let recvs: Vec<&str> = acqs.iter().map(|a| a.recv.as_str()).collect();
        assert_eq!(recvs, vec!["self.files", "self.index", "x"]);
        assert_eq!(acqs[1].kind, AcqKind::Unpoisoned);
    }

    #[test]
    fn allowed_suffix_forms() {
        assert!(allowed_suffix(b".unwrap()"));
        assert!(allowed_suffix(b".expect(\"poisoned\") "));
        assert!(allowed_suffix(b".unwrap_or_else(PoisonError::into_inner)"));
        assert!(allowed_suffix(b""));
        assert!(!allowed_suffix(b".unwrap().len"));
        assert!(!allowed_suffix(b" + 1"));
    }

    const STRUCT_AB: &str = "struct S {\n    a: Mutex<u8>,\n    b: Mutex<u8>,\n}\n";

    #[test]
    fn direct_nesting_produces_edge() {
        let src = format!(
            "{STRUCT_AB}\
             impl S {{\n\
                 fn f(&self) {{\n\
                     let g = self.a.lock().unwrap();\n\
                     let h = self.b.lock().unwrap();\n\
                     drop(h);\n\
                     drop(g);\n\
                 }}\n\
             }}\n"
        );
        let sf = file("m.rs", &src);
        let a = analyze(&[sf]);
        assert_eq!(a.edges.len(), 1);
        assert_eq!(a.edges[0].frm, "m.a");
        assert_eq!(a.edges[0].to, "m.b");
    }

    #[test]
    fn guard_scope_ends_at_block() {
        let src = format!(
            "{STRUCT_AB}\
             impl S {{\n\
                 fn f(&self) {{\n\
                     {{\n\
                         let g = self.a.lock().unwrap();\n\
                         let _x = *g;\n\
                     }}\n\
                     let h = self.b.lock().unwrap();\n\
                     let _y = *h;\n\
                 }}\n\
             }}\n"
        );
        let sf = file("m.rs", &src);
        let a = analyze(&[sf]);
        assert!(a.edges.is_empty(), "edges: {:?}", a.edges);
    }

    #[test]
    fn transitive_edge_via_callee_summary() {
        let src = format!(
            "{STRUCT_AB}\
             impl S {{\n\
                 fn inner(&self) {{\n\
                     let g = self.b.lock().unwrap();\n\
                     let _ = *g;\n\
                 }}\n\
                 fn outer(&self) {{\n\
                     let g = self.a.lock().unwrap();\n\
                     self.inner();\n\
                     drop(g);\n\
                 }}\n\
             }}\n"
        );
        let sf = file("m.rs", &src);
        let a = analyze(&[sf]);
        let has = a
            .edges
            .iter()
            .any(|e| e.frm == "m.a" && e.to == "m.b" && e.via.as_deref() == Some("inner"));
        assert!(has, "edges: {:?}", a.edges);
    }

    #[test]
    fn stoplist_blocks_receiver_resolution() {
        // `x.clear()` must not resolve to a tree fn named `clear` in
        // another file even when globally unique.
        let f1 = file(
            "a.rs",
            "struct A {\n\
                 l: Mutex<u8>,\n\
             }\n\
             impl A {\n\
                 fn clear(&self) {\n\
                     let g = self.l.lock().unwrap();\n\
                     let _ = *g;\n\
                 }\n\
             }\n",
        );
        let f2 = file(
            "b.rs",
            "struct B {\n\
                 m: Mutex<u8>,\n\
             }\n\
             impl B {\n\
                 fn f(&self, x: &mut Vec<u8>) {\n\
                     let g = self.m.lock().unwrap();\n\
                     x.clear();\n\
                     drop(g);\n\
                 }\n\
             }\n",
        );
        let a = analyze(&[f1, f2]);
        assert!(
            a.edges.iter().all(|e| !(e.frm == "b.m" && e.to == "a.l")),
            "edges: {:?}",
            a.edges
        );
    }

    #[test]
    fn cycle_detection() {
        let mk = |frm: &str, to: &str| Edge {
            frm: frm.into(),
            to: to.into(),
            file: "x.rs".into(),
            line: 1,
            via: None,
        };
        assert!(find_cycle(&[mk("a", "b"), mk("b", "c")]).is_none());
        let cyc = find_cycle(&[mk("a", "b"), mk("b", "a")]).expect("cycle");
        assert_eq!(cyc.first(), cyc.last());
    }

    #[test]
    fn hierarchy_rank_violation() {
        let order = vec![("m.a".to_string(), 10), ("m.b".to_string(), 20)];
        let locks: BTreeSet<String> =
            ["m.a".to_string(), "m.b".to_string()].into_iter().collect();
        let bad = Edge {
            frm: "m.b".into(),
            to: "m.a".into(),
            file: "m.rs".into(),
            line: 4,
            via: None,
        };
        let f = hierarchy_findings(&order, "lock-order.txt", &locks, &[bad]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "lock-order");
        assert_eq!(f[0].key, "m.b->m.a");
    }
}
