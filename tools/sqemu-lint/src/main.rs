//! CLI: `cargo run -p sqemu-lint [-- --root <repo> --json <out.json>]`
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/configuration error.

use sqemu_lint::Config;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                let Some(v) = args.next() else {
                    eprintln!("sqemu-lint: --root needs a path");
                    return ExitCode::from(2);
                };
                root = PathBuf::from(v);
            }
            "--json" => {
                let Some(v) = args.next() else {
                    eprintln!("sqemu-lint: --json needs a path");
                    return ExitCode::from(2);
                };
                json = Some(PathBuf::from(v));
            }
            "--help" | "-h" => {
                println!(
                    "sqemu-lint — fleet invariant analyzer\n\n\
                     USAGE: sqemu-lint [--root <repo>] [--json <out.json>]\n\n\
                     Checks rust/src against the lock hierarchy \
                     (tools/sqemu-lint/lock-order.txt), durability \
                     annotations, and panic/serving cones. Exceptions: \
                     tools/sqemu-lint/allowlist.txt."
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("sqemu-lint: unknown argument {other:?}");
                return ExitCode::from(2);
            }
        }
    }

    let cfg = Config::for_tree(&root);
    if !cfg.src_dir.is_dir() {
        eprintln!(
            "sqemu-lint: {} is not a directory (run from the repo root \
             or pass --root)",
            cfg.src_dir.display()
        );
        return ExitCode::from(2);
    }

    match sqemu_lint::run_with(&cfg) {
        Ok(report) => {
            print!("{}", report.render_text());
            if let Some(path) = json {
                if let Err(e) = std::fs::write(&path, report.render_json()) {
                    eprintln!(
                        "sqemu-lint: writing {}: {e}",
                        path.display()
                    );
                    return ExitCode::from(2);
                }
            }
            if report.is_clean() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("sqemu-lint: error: {e:#}");
            ExitCode::from(2)
        }
    }
}
