//! Findings, the aggregate report, and its JSON serialization.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule slug, e.g. `lock-order`, `panic-cone`, `durability-unpaired`.
    pub rule: String,
    /// Line-number-free identity used for allowlist matching.
    pub key: String,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(
        rule: &str,
        key: String,
        file: &str,
        line: usize,
        message: String,
    ) -> Finding {
        Finding {
            rule: rule.to_string(),
            key,
            file: file.to_string(),
            line,
            message,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct Stats {
    pub files: usize,
    pub fns: usize,
    pub locks: usize,
    pub edges: usize,
    pub unresolved_acquisitions: usize,
}

#[derive(Debug, Default)]
pub struct Report {
    /// Findings that fail the build.
    pub findings: Vec<Finding>,
    /// Findings matched (and justified) by the allowlist.
    pub suppressed: Vec<Finding>,
    pub stats: Stats,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable listing for the terminal / CI log.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let s = &self.stats;
        let _ = writeln!(
            out,
            "sqemu-lint: {} files, {} fns, {} locks, {} lock edges \
             ({} unresolved acquisitions)",
            s.files, s.fns, s.locks, s.edges, s.unresolved_acquisitions
        );
        for f in &self.findings {
            let _ = writeln!(
                out,
                "error[{}]: {} ({}:{}) [key: {}]",
                f.rule, f.message, f.file, f.line, f.key
            );
        }
        for f in &self.suppressed {
            let _ = writeln!(
                out,
                "allowed[{}]: {} ({}:{})",
                f.rule, f.message, f.file, f.line
            );
        }
        if self.findings.is_empty() {
            let _ = writeln!(
                out,
                "OK: no findings ({} allowlisted)",
                self.suppressed.len()
            );
        } else {
            let _ = writeln!(
                out,
                "FAIL: {} finding(s) ({} allowlisted)",
                self.findings.len(),
                self.suppressed.len()
            );
        }
        out
    }

    /// JSON artifact for CI upload. Hand-rolled: the tool is std-only.
    pub fn render_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n  \"stats\": {");
        let s = &self.stats;
        let _ = write!(
            out,
            "\"files\": {}, \"fns\": {}, \"locks\": {}, \"edges\": {}, \
             \"unresolved_acquisitions\": {}",
            s.files, s.fns, s.locks, s.edges, s.unresolved_acquisitions
        );
        out.push_str("},\n");
        let section = |name: &str, list: &[Finding]| -> String {
            let mut buf = String::new();
            let _ = write!(buf, "  \"{name}\": [");
            for (i, f) in list.iter().enumerate() {
                if i > 0 {
                    buf.push(',');
                }
                let _ = write!(
                    buf,
                    "\n    {{\"rule\": {}, \"key\": {}, \"file\": {}, \
                     \"line\": {}, \"message\": {}}}",
                    json_str(&f.rule),
                    json_str(&f.key),
                    json_str(&f.file),
                    f.line,
                    json_str(&f.message)
                );
            }
            if !list.is_empty() {
                buf.push_str("\n  ");
            }
            buf.push(']');
            buf
        };
        out.push_str(&section("findings", &self.findings));
        out.push_str(",\n");
        out.push_str(&section("suppressed", &self.suppressed));
        out.push_str("\n}\n");
        out
    }
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes() {
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn json_shape() {
        let mut r = Report::default();
        r.findings.push(Finding::new(
            "lock-order",
            "a->b".to_string(),
            "x.rs",
            3,
            "bad".to_string(),
        ));
        let j = r.render_json();
        assert!(j.contains("\"rule\": \"lock-order\""));
        assert!(j.contains("\"line\": 3"));
        assert!(j.contains("\"suppressed\": []"));
    }
}
