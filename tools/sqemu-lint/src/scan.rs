//! Source scanning: comment/string stripping and the per-file model
//! (functions, lock fields, test spans, `// lint:` annotations).
//!
//! Everything downstream works on `code` — the original text with
//! comments and string/char literals blanked to spaces (newlines kept),
//! so byte offsets and line numbers always refer to the real file.
//! The mirror image, `comments`, keeps only comment text and is where
//! annotations are read from, so an annotation can never be spoofed
//! from inside a string literal (nor a lock hidden inside a comment).

use std::collections::{BTreeMap, HashMap};

/// Identifier byte: `[A-Za-z0-9_]`.
pub fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Advance past ASCII whitespace.
pub fn skip_ws(s: &[u8], mut i: usize) -> usize {
    while i < s.len() && s[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Walk backwards past ASCII whitespace. Returns the index one past the
/// last non-whitespace byte at or before `i` (i.e. an exclusive end).
pub fn skip_ws_back(s: &[u8], mut i: usize) -> usize {
    while i > 0 && s[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    i
}

/// Parse an identifier starting exactly at `i`; returns (end, name).
pub fn ident_at(s: &[u8], i: usize) -> Option<(usize, String)> {
    let mut j = i;
    while j < s.len() && is_ident(s[j]) {
        j += 1;
    }
    if j == i {
        return None;
    }
    Some((j, String::from_utf8_lossy(&s[i..j]).into_owned()))
}

/// Is `word` present at offset `i` with word boundaries on both sides?
pub fn word_at(s: &[u8], i: usize, word: &str) -> bool {
    let w = word.as_bytes();
    if i + w.len() > s.len() || &s[i..i + w.len()] != w {
        return false;
    }
    if i > 0 && is_ident(s[i - 1]) {
        return false;
    }
    if i + w.len() < s.len() && is_ident(s[i + w.len()]) {
        return false;
    }
    true
}

/// Offsets of all word-boundary occurrences of `word` in `s`.
pub fn find_words(s: &[u8], word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    if word.is_empty() || s.len() < word.len() {
        return out;
    }
    for i in 0..=s.len() - word.len() {
        if word_at(s, i, word) {
            out.push(i);
        }
    }
    out
}

pub fn count_newlines(s: &[u8]) -> usize {
    s.iter().filter(|&&b| b == b'\n').count()
}

/// Blank comments and string/char literals out of `text`.
///
/// Returns `(code, comments)`, both the same byte length as the input:
/// `code` keeps code bytes (literal/comment bytes become spaces),
/// `comments` keeps comment bytes (everything else becomes spaces).
/// Newlines survive in both so line numbers stay aligned.
pub fn strip_code(text: &[u8]) -> (Vec<u8>, Vec<u8>) {
    #[derive(PartialEq)]
    enum Mode {
        Code,
        LineComment,
        BlockComment,
        Str,
        RawStr,
    }
    let n = text.len();
    let mut code = Vec::with_capacity(n);
    let mut comments = Vec::with_capacity(n);
    let mut mode = Mode::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = text[i];
        let nxt = if i + 1 < n { text[i + 1] } else { 0 };
        match mode {
            Mode::Code => {
                if c == b'/' && nxt == b'/' {
                    mode = Mode::LineComment;
                    code.extend_from_slice(b"  ");
                    comments.extend_from_slice(b"//");
                    i += 2;
                    continue;
                }
                if c == b'/' && nxt == b'*' {
                    mode = Mode::BlockComment;
                    block_depth = 1;
                    code.extend_from_slice(b"  ");
                    comments.extend_from_slice(b"  ");
                    i += 2;
                    continue;
                }
                if c == b'"' || (c == b'b' && nxt == b'"') {
                    if c == b'b' {
                        code.push(b'b');
                        comments.push(b' ');
                        i += 1;
                    }
                    mode = Mode::Str;
                    code.push(b'"');
                    comments.push(b' ');
                    i += 1;
                    continue;
                }
                if c == b'r' && (nxt == b'"' || nxt == b'#') {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && text[j] == b'#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && text[j] == b'"' {
                        raw_hashes = h;
                        mode = Mode::RawStr;
                        for k in i..=j {
                            code.push(if text[k] == b'\n' { b'\n' } else { b' ' });
                            comments.push(b' ');
                        }
                        i = j + 1;
                        continue;
                    }
                }
                if c == b'\'' {
                    // Char literal ('x', '\n', '\u{..}') vs lifetime ('a).
                    let j = i + 1;
                    if j < n && text[j] == b'\\' {
                        let mut k = j + 1;
                        while k < n && text[k] != b'\'' {
                            k += 1;
                        }
                        let stop = (k + 1).min(n);
                        for _ in i..stop {
                            code.push(b' ');
                            comments.push(b' ');
                        }
                        i = k + 1;
                        continue;
                    }
                    if j + 1 < n && text[j + 1] == b'\'' {
                        code.extend_from_slice(b"   ");
                        comments.extend_from_slice(b"   ");
                        i = j + 2;
                        continue;
                    }
                    // Lifetime: keep the quote (harmless to downstream).
                    code.push(b'\'');
                    comments.push(b' ');
                    i += 1;
                    continue;
                }
                code.push(c);
                comments.push(if c == b'\n' { b'\n' } else { b' ' });
                i += 1;
            }
            Mode::LineComment => {
                if c == b'\n' {
                    mode = Mode::Code;
                    code.push(b'\n');
                    comments.push(b'\n');
                } else {
                    code.push(b' ');
                    comments.push(c);
                }
                i += 1;
            }
            Mode::BlockComment => {
                if c == b'/' && nxt == b'*' {
                    block_depth += 1;
                    code.extend_from_slice(b"  ");
                    comments.extend_from_slice(b"  ");
                    i += 2;
                    continue;
                }
                if c == b'*' && nxt == b'/' {
                    block_depth -= 1;
                    code.extend_from_slice(b"  ");
                    comments.extend_from_slice(b"  ");
                    i += 2;
                    if block_depth == 0 {
                        mode = Mode::Code;
                    }
                    continue;
                }
                code.push(if c == b'\n' { b'\n' } else { b' ' });
                comments.push(c);
                i += 1;
            }
            Mode::Str => {
                if c == b'\\' {
                    if nxt == b'\n' {
                        code.extend_from_slice(b" \n");
                        comments.extend_from_slice(b" \n");
                    } else {
                        // Escape at EOF still emits two bytes in Python's
                        // reference; clamp so lengths match the input.
                        let take = if i + 1 < n { 2 } else { 1 };
                        for _ in 0..take {
                            code.push(b' ');
                            comments.push(b' ');
                        }
                    }
                    i += 2;
                    continue;
                }
                if c == b'"' {
                    mode = Mode::Code;
                    code.push(b'"');
                    comments.push(b' ');
                    i += 1;
                    continue;
                }
                code.push(if c == b'\n' { b'\n' } else { b' ' });
                comments.push(if c == b'\n' { b'\n' } else { b' ' });
                i += 1;
            }
            Mode::RawStr => {
                if c == b'"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && text[j] == b'#' && h < raw_hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == raw_hashes {
                        mode = Mode::Code;
                        for _ in i..j {
                            code.push(b' ');
                            comments.push(b' ');
                        }
                        i = j;
                        continue;
                    }
                }
                code.push(if c == b'\n' { b'\n' } else { b' ' });
                comments.push(if c == b'\n' { b'\n' } else { b' ' });
                i += 1;
            }
        }
    }
    (code, comments)
}

#[derive(Debug, Clone)]
pub struct FnInfo {
    pub name: String,
    /// Byte offset of the `fn` keyword in `code`.
    pub start: usize,
    /// Byte offset of the body's `{`.
    pub body: usize,
    /// Byte offset of the body's matching `}`.
    pub end: usize,
    pub start_line: usize,
    pub end_line: usize,
}

pub struct SourceFile {
    /// Path relative to the source root, e.g. `coordinator/server.rs`.
    pub rel: String,
    /// Module path used in canonical lock names, e.g. `coordinator/server`
    /// (`/mod` collapsed to the directory name).
    pub module: String,
    pub code: Vec<u8>,
    pub code_lines: Vec<String>,
    pub comment_lines: Vec<String>,
    /// 1-based inclusive line spans of `#[cfg(test)]` items.
    pub test_spans: Vec<(usize, usize)>,
    pub fns: Vec<FnInfo>,
    /// Lock field name -> "Mutex" | "RwLock".
    pub lock_fields: BTreeMap<String, String>,
    /// Code line -> `// lint:` annotations attached to it.
    pub annotations: HashMap<usize, Vec<(String, String)>>,
    /// Byte offsets of every `\n` in `code`, for offset->line lookups.
    newline_pos: Vec<usize>,
}

impl SourceFile {
    pub fn parse(rel: &str, text: &[u8]) -> SourceFile {
        let (code, comments) = strip_code(text);
        let mut module = rel.strip_suffix(".rs").unwrap_or(rel).to_string();
        if let Some(m) = module.strip_suffix("/mod") {
            module = m.to_string();
        }
        let to_lines = |buf: &[u8]| -> Vec<String> {
            String::from_utf8_lossy(buf)
                .split('\n')
                .map(|l| l.to_string())
                .collect()
        };
        let code_lines = to_lines(&code);
        let comment_lines = to_lines(&comments);
        let newline_pos: Vec<usize> = code
            .iter()
            .enumerate()
            .filter(|(_, &b)| b == b'\n')
            .map(|(i, _)| i)
            .collect();
        let mut sf = SourceFile {
            rel: rel.to_string(),
            module,
            code,
            code_lines,
            comment_lines,
            test_spans: Vec::new(),
            fns: Vec::new(),
            lock_fields: BTreeMap::new(),
            annotations: HashMap::new(),
            newline_pos,
        };
        sf.test_spans = sf.find_test_spans();
        sf.fns = sf.find_functions();
        sf.lock_fields = sf.find_lock_fields();
        sf.annotations = sf.find_annotations();
        sf
    }

    /// 1-based line number of a byte offset into `code`.
    pub fn line_of(&self, offset: usize) -> usize {
        self.newline_pos.partition_point(|&p| p < offset) + 1
    }

    pub fn in_test(&self, line: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= line && line <= b)
    }

    fn find_test_spans(&self) -> Vec<(usize, usize)> {
        let mut spans = Vec::new();
        let lines = &self.code_lines;
        for (idx, line) in lines.iter().enumerate() {
            if !line.contains("#[cfg(test)]") {
                continue;
            }
            let mut j = idx;
            while j < lines.len() && !lines[j].contains('{') {
                j += 1;
            }
            if j >= lines.len() {
                continue;
            }
            let mut depth: i64 = 0;
            for (k, lk) in lines.iter().enumerate().skip(j) {
                depth += lk.matches('{').count() as i64;
                depth -= lk.matches('}').count() as i64;
                if depth <= 0 {
                    spans.push((idx + 1, k + 1));
                    break;
                }
            }
        }
        spans
    }

    fn find_functions(&self) -> Vec<FnInfo> {
        let code = &self.code;
        let mut fns: Vec<FnInfo> = Vec::new();
        for p in find_words(code, "fn") {
            // `fn` then at least one whitespace byte, then the name.
            let q = skip_ws(code, p + 2);
            if q == p + 2 {
                continue;
            }
            let Some((name_end, name)) = ident_at(code, q) else {
                continue;
            };
            // Body start: the next `{` before any `;` (skips trait decls).
            let mut j = name_end;
            let mut body = None;
            while j < code.len() {
                match code[j] {
                    b';' => break,
                    b'{' => {
                        body = Some(j);
                        break;
                    }
                    _ => j += 1,
                }
            }
            let Some(body) = body else { continue };
            let mut depth: i64 = 0;
            let mut k = body;
            while k < code.len() {
                match code[k] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            fns.push(FnInfo {
                name,
                start: p,
                body,
                end: k,
                start_line: self.line_of(p),
                end_line: self.line_of(k),
            });
        }
        // Drop fns nested inside another fn's body: only the outermost
        // definitions take part in call resolution.
        let keep: Vec<bool> = fns
            .iter()
            .map(|f| {
                !fns.iter()
                    .any(|g| g.start < f.start && g.end > f.end)
            })
            .collect();
        fns.into_iter()
            .zip(keep)
            .filter(|(_, k)| *k)
            .map(|(f, _)| f)
            .collect()
    }

    /// Match a struct-field lock declaration on one line:
    /// `^\s*(pub(...)?\s+)?NAME\s*:\s*(Arc<)?(Vec<)?(Mutex|RwLock)<`.
    fn lock_field_on_line(line: &str) -> Option<(String, String)> {
        let s = line.as_bytes();
        let mut i = skip_ws(s, 0);
        if word_at(s, i, "pub") {
            let mut j = i + 3;
            if j < s.len() && s[j] == b'(' {
                j += 1;
                while j < s.len()
                    && (s[j].is_ascii_lowercase() || s[j] == b'_' || s[j] == b':')
                {
                    j += 1;
                }
                if j >= s.len() || s[j] != b')' {
                    return None;
                }
                j += 1;
            }
            let k = skip_ws(s, j);
            if k == j {
                return None; // need whitespace after `pub` / `pub(..)`
            }
            i = k;
        }
        let (mut j, name) = ident_at(s, i)?;
        j = skip_ws(s, j);
        if j >= s.len() || s[j] != b':' {
            return None;
        }
        j = skip_ws(s, j + 1);
        let rest = &s[j..];
        let rest = rest.strip_prefix(b"Arc<").unwrap_or(rest);
        let rest = rest.strip_prefix(b"Vec<").unwrap_or(rest);
        if rest.starts_with(b"Mutex<") {
            Some((name, "Mutex".to_string()))
        } else if rest.starts_with(b"RwLock<") {
            Some((name, "RwLock".to_string()))
        } else {
            None
        }
    }

    fn find_lock_fields(&self) -> BTreeMap<String, String> {
        let mut fields = BTreeMap::new();
        for (idx, line) in self.code_lines.iter().enumerate() {
            let ln = idx + 1;
            if self.in_test(ln) {
                continue;
            }
            if self
                .fns
                .iter()
                .any(|f| f.start_line <= ln && ln <= f.end_line)
            {
                continue;
            }
            if let Some((name, kind)) = Self::lock_field_on_line(line) {
                fields.insert(name, kind);
            }
        }
        fields
    }

    /// All `lint: name(arg)` annotations in one comment line.
    fn annotations_on_line(line: &str) -> Vec<(String, String)> {
        let s = line.as_bytes();
        let mut out = Vec::new();
        let mut i = 0usize;
        while i + 5 <= s.len() {
            if &s[i..i + 5] != b"lint:" {
                i += 1;
                continue;
            }
            let mut j = skip_ws(s, i + 5);
            let name_start = j;
            while j < s.len() && (s[j].is_ascii_lowercase() || s[j] == b'-') {
                j += 1;
            }
            if j == name_start {
                i += 5;
                continue;
            }
            let name = String::from_utf8_lossy(&s[name_start..j]).into_owned();
            let mut arg = String::new();
            if j < s.len() && s[j] == b'(' {
                let arg_start = j + 1;
                let mut k = arg_start;
                while k < s.len()
                    && (s[k].is_ascii_lowercase()
                        || s[k].is_ascii_digit()
                        || s[k] == b'_'
                        || s[k] == b'-')
                {
                    k += 1;
                }
                if k > arg_start && k < s.len() && s[k] == b')' {
                    arg = String::from_utf8_lossy(&s[arg_start..k]).into_owned();
                    j = k + 1;
                }
            }
            out.push((name, arg));
            i = j;
        }
        out
    }

    fn find_annotations(&self) -> HashMap<usize, Vec<(String, String)>> {
        let mut anns: HashMap<usize, Vec<(String, String)>> = HashMap::new();
        let mut pending: Vec<(String, String)> = Vec::new();
        for idx in 0..self.comment_lines.len() {
            let ln = idx + 1;
            let found = Self::annotations_on_line(&self.comment_lines[idx]);
            let has_code = !self.code_lines[idx].trim().is_empty();
            if !found.is_empty() && has_code {
                anns.entry(ln).or_default().extend(found);
            } else if !found.is_empty() {
                pending.extend(found);
            } else if has_code && !pending.is_empty() {
                anns.entry(ln).or_default().extend(std::mem::take(&mut pending));
            }
        }
        anns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_preserves_length_and_blanks_strings() {
        let src = br#"let s = "a // not a comment"; // real comment"#;
        let (code, comments) = strip_code(src);
        assert_eq!(code.len(), src.len());
        assert_eq!(comments.len(), src.len());
        let code_s = String::from_utf8_lossy(&code).into_owned();
        assert!(!code_s.contains("not a comment"));
        assert!(code_s.contains("let s"));
        let com_s = String::from_utf8_lossy(&comments).into_owned();
        assert!(com_s.contains("real comment"));
    }

    #[test]
    fn strip_handles_nested_block_comments_and_raw_strings() {
        let src = b"a /* x /* y */ z */ b r#\"quote \" inside\"# c";
        let (code, _) = strip_code(src);
        let code_s = String::from_utf8_lossy(&code).into_owned();
        assert!(code_s.contains('a'));
        assert!(code_s.contains('b'));
        assert!(code_s.contains('c'));
        assert!(!code_s.contains('y'));
        assert!(!code_s.contains("inside"));
    }

    #[test]
    fn strip_char_literals_but_not_lifetimes() {
        let src = b"match c { '{' => 1, _ => 0 }; fn f<'a>(x: &'a u8) {}";
        let (code, _) = strip_code(src);
        let code_s = String::from_utf8_lossy(&code).into_owned();
        // The '{' literal must not unbalance brace matching.
        let opens = code_s.matches('{').count();
        let closes = code_s.matches('}').count();
        assert_eq!(opens, closes);
        assert!(code_s.contains("'a"));
    }

    #[test]
    fn lock_field_parsing() {
        let cases = [
            ("    files: Mutex<HashMap<u32, File>>,", Some(("files", "Mutex"))),
            ("    pub l1: RwLock<Vec<u64>>,", Some(("l1", "RwLock"))),
            ("    pub(crate) inner: Mutex<Inner>,", Some(("inner", "Mutex"))),
            ("    shards: Vec<Mutex<Shard>>,", Some(("shards", "Mutex"))),
            ("    index: Arc<Mutex<Index>>,", Some(("index", "Mutex"))),
            ("    name: String,", None),
            ("    // files: Mutex<...> in a comment", None),
        ];
        for (line, want) in cases {
            let got = SourceFile::lock_field_on_line(line);
            match want {
                Some((f, k)) => {
                    let (gf, gk) = got.expect(line);
                    assert_eq!((gf.as_str(), gk.as_str()), (f, k), "{line}");
                }
                None => assert!(got.is_none(), "{line}"),
            }
        }
    }

    #[test]
    fn annotations_attach_to_next_code_line() {
        let src = b"// lint: durable-before(job)\nstore.persist(&rec);\nlet x = 1; // lint: mutates(job)\n";
        let sf = SourceFile::parse("a.rs", src);
        assert_eq!(
            sf.annotations.get(&2),
            Some(&vec![("durable-before".to_string(), "job".to_string())])
        );
        assert_eq!(
            sf.annotations.get(&3),
            Some(&vec![("mutates".to_string(), "job".to_string())])
        );
    }

    #[test]
    fn fn_spans_and_test_spans() {
        let src = b"fn outer(a: u8) -> u8 {\n    let f = |x: u8| x + 1;\n    f(a)\n}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn inner() { assert!(true); }\n}\n";
        let sf = SourceFile::parse("m.rs", src);
        let names: Vec<&str> = sf.fns.iter().map(|f| f.name.as_str()).collect();
        assert!(names.contains(&"outer"));
        let outer = sf.fns.iter().find(|f| f.name == "outer").unwrap();
        assert_eq!(outer.start_line, 1);
        assert_eq!(outer.end_line, 4);
        assert!(sf.in_test(9));
        assert!(!sf.in_test(1));
    }
}
