//! Fixture tests proving every lint class fires (and stays quiet on a
//! clean tree), plus the real-tree gate: the checked-in rust/src must
//! analyze clean against the checked-in hierarchy and allowlist.

use sqemu_lint::{run_with, Config, Report};
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(name)
}

fn run_fixture(name: &str, tweak: impl FnOnce(&mut Config)) -> Report {
    let dir = fixture(name);
    let mut cfg = Config::bare(dir.join("src"));
    let order = dir.join("lock-order.txt");
    if order.exists() {
        cfg.lock_order = Some(order);
    }
    let allow = dir.join("allowlist.txt");
    if allow.exists() {
        cfg.allowlist = Some(allow);
    }
    tweak(&mut cfg);
    run_with(&cfg).expect("fixture analysis runs")
}

fn rules(report: &Report) -> Vec<&str> {
    report.findings.iter().map(|f| f.rule.as_str()).collect()
}

#[test]
fn cycle_fixture_reports_lock_cycle() {
    let r = run_fixture("cycle", |_| {});
    assert_eq!(rules(&r), vec!["lock-cycle"], "{:#?}", r.findings);
    let f = &r.findings[0];
    assert!(f.key.contains("m.a") && f.key.contains("m.b"), "{f:?}");
}

#[test]
fn order_fixture_reports_hierarchy_violations() {
    let r = run_fixture("order", |_| {});
    let rs = rules(&r);
    assert!(rs.contains(&"lock-order"), "{:#?}", r.findings);
    assert!(rs.contains(&"lock-unranked"), "{:#?}", r.findings);
    assert!(rs.contains(&"rank-stale"), "{:#?}", r.findings);
    assert_eq!(r.findings.len(), 3, "{:#?}", r.findings);
    let order = r.findings.iter().find(|f| f.rule == "lock-order").unwrap();
    assert_eq!(order.key, "m.a->m.b");
    let unranked = r.findings.iter().find(|f| f.rule == "lock-unranked").unwrap();
    assert_eq!(unranked.key, "m.c");
    let stale = r.findings.iter().find(|f| f.rule == "rank-stale").unwrap();
    assert_eq!(stale.key, "m.zz");
}

#[test]
fn durability_fixture_reports_each_case_once() {
    let r = run_fixture("durability", |cfg| {
        cfg.dur_dirs = vec!["control/".to_string()];
    });
    let mut rs = rules(&r);
    rs.sort_unstable();
    assert_eq!(
        rs,
        vec![
            "durability-flip-unflagged",
            "durability-missing-flush",
            "durability-unannotated",
            "durability-unpaired",
        ],
        "{:#?}",
        r.findings
    );
    for (rule, fun) in [
        ("durability-unannotated", "unannotated"),
        ("durability-unpaired", "unpaired"),
        ("durability-flip-unflagged", "flip_unflagged"),
        ("durability-missing-flush", "flip_unflushed"),
    ] {
        let f = r.findings.iter().find(|f| f.rule == rule).unwrap();
        assert_eq!(f.key, format!("control/store.rs:{fun}"), "{f:?}");
    }
}

#[test]
fn cones_fixture_reports_panic_and_index() {
    let r = run_fixture("cones", |cfg| {
        cfg.panic_files = vec!["recover.rs".to_string()];
        cfg.index_files = vec!["recover.rs".to_string()];
    });
    let mut rs = rules(&r);
    rs.sort_unstable();
    assert_eq!(rs, vec!["index-cone", "panic-cone"], "{:#?}", r.findings);
    for f in &r.findings {
        assert_eq!(f.key, "recover.rs:recover_index", "{f:?}");
    }
}

#[test]
fn serving_fixture_reports_transitive_lock() {
    let r = run_fixture("serving", |cfg| {
        cfg.serving_file = "shard.rs".to_string();
        cfg.serving_fns = vec!["serve".to_string()];
    });
    assert_eq!(rules(&r), vec!["serving-lock"], "{:#?}", r.findings);
    assert_eq!(r.findings[0].key, "serve:shard.stash");
}

#[test]
fn allowlist_suppresses_and_flags_stale_entries() {
    let r = run_fixture("allow_stale", |cfg| {
        cfg.serving_file = "shard.rs".to_string();
        cfg.serving_fns = vec!["serve".to_string()];
    });
    assert_eq!(rules(&r), vec!["allowlist-stale"], "{:#?}", r.findings);
    assert!(r.findings[0].key.contains("m.x->m.y"), "{:?}", r.findings[0]);
    assert_eq!(r.suppressed.len(), 1, "{:#?}", r.suppressed);
    assert_eq!(r.suppressed[0].rule, "serving-lock");
}

#[test]
fn clean_fixture_has_no_findings() {
    let r = run_fixture("clean", |cfg| {
        cfg.dur_dirs = vec![String::new()];
    });
    assert!(r.findings.is_empty(), "{:#?}", r.findings);
    assert!(r.suppressed.is_empty(), "{:#?}", r.suppressed);
    assert_eq!(r.stats.locks, 2);
    assert_eq!(r.stats.edges, 1);
}

/// The gate the CI job enforces: the real tree, with its checked-in
/// hierarchy and allowlist, must be clean — and the allowlist must be
/// fully live (exactly the serve_slot stash exception).
#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let cfg = Config::for_tree(&root);
    let report = run_with(&cfg).expect("real-tree analysis runs");
    assert!(
        report.findings.is_empty(),
        "sqemu-lint findings on the real tree:\n{:#?}",
        report.findings
    );
    assert_eq!(
        report.suppressed.len(),
        1,
        "expected exactly the serve_slot stash exception:\n{:#?}",
        report.suppressed
    );
    assert_eq!(report.suppressed[0].rule, "serving-lock");
    assert_eq!(report.suppressed[0].key, "serve_slot:coordinator/ring.stash");
    assert!(report.stats.locks >= 25, "stats: {:?}", report.stats);
    assert!(report.stats.edges >= 10, "stats: {:?}", report.stats);
}
